"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE — a
``lax.scan`` over 94 layers reports one layer's FLOPs.  Since this
framework scans over layers, micro-batches, attention chunks and MoE
segments, the roofline terms must multiply loop bodies by their trip
counts.  This walker parses the post-optimization HLO text:

  * splits it into named computations and builds a per-computation
    symbol table (instruction name -> shape) so dot contraction sizes
    can be recovered from operand shapes,
  * computes per-computation FLOPs (dot/conv), bytes touched, and
    collective bytes (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute output bytes),
  * recurses through fusion/call/conditional and multiplies ``while``
    bodies by the trip count from the ``known_trip_count`` backend
    config (emitted for lax.scan/fori_loop), falling back to the
    condition's comparison constant.

Validated in tests against cost_analysis() on loop-free programs and
against hand-counted looped programs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# Zero-cost ops: tuple plumbing, aliasing views, metadata.
_FREE_OPS = {
    "get-tuple-element", "tuple", "parameter", "bitcast", "constant",
    "after-all", "reshape", "optimization-barrier", "partition-id",
    "replica-id", "rng-bit-generator",
}

_ELEMWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "exponential", "tanh", "rsqrt",
    "sqrt", "power", "log", "logistic", "maximum", "minimum", "negate",
    "exponential-minus-one", "log-plus-one", "cosine", "sine",
}


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shape_text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of(shape_text: str) -> int:
    total = 0
    for _, dims in _shapes_in(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    # XLA CPU float-normalization upcasts bf16 programs to f32, so compiled
    # collectives are all f32.  ``coll_bytes_tpu`` counts collectives whose
    # operand is a convert-from-bf16 at bf16 width — the native-TPU volume.
    coll_bytes_tpu: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_loops: int = 0

    def add(self, other: "Cost", factor: float = 1.0):
        self.flops += other.flops * factor
        self.bytes += other.bytes * factor
        self.coll_bytes += other.coll_bytes * factor
        self.coll_bytes_tpu += other.coll_bytes_tpu * factor
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * factor
        self.unknown_loops += other.unknown_loops


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{$")
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_AT = re.compile(r"([a-z][a-z0-9\-]*)\(")


def _parse_instr(line: str):
    """Parse '%name = <shape> opcode(operands), attrs' robustly.

    Tuple result shapes may contain '/*index=N*/' comments (with '='), so
    we match the result by paren balance instead of a regex.
    Returns (name, result_shape, opcode, rest) or None.
    """
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    s = line[m.end():]
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        result_shape = s[: i + 1]
        s = s[i + 1 :]
    else:
        mo = _OPCODE_AT.search(s)
        if not mo:
            return None
        result_shape = s[: mo.start()]
        s = s[mo.start():]
    mo = _OPCODE_AT.match(s.lstrip())
    if not mo:
        return None
    opcode = mo.group(1)
    rest = s.lstrip()[mo.end():]
    return name, result_shape, opcode, rest
_PARAM_DECL = re.compile(r"([\w.\-]+):\s*([a-z0-9]+\[[\d,]*\])")
_TRIP_BC = re.compile(r'known_trip_count[^\d]*"n":"(\d+)"')
_OPERANDS = re.compile(r"%([\w.\-]+)")


class _Computation:
    def __init__(self, name: str, header: str):
        self.name = name
        self.lines: List[str] = []
        self.shapes: Dict[str, str] = {}
        self.defs: Dict[str, str] = {}  # name -> defining line
        # leaf parameters declared in the header
        paren = header[header.find("(") : header.rfind("->")]
        for pname, pshape in _PARAM_DECL.findall(paren):
            self.shapes[pname] = pshape


def split_computations(hlo: str) -> Dict[str, "_Computation"]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(s)
            if m:
                cur = _Computation(m.group(1), s)
                comps[cur.name] = cur
            continue
        if s == "}":
            cur = None
            continue
        cur.lines.append(s)
        mi = _parse_instr(s)
        if mi:
            cur.shapes[mi[0]] = mi[1]
            cur.defs[mi[0]] = s
    return comps


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = split_computations(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self.entry = None
        for name in self.comps:
            if name.startswith("main"):
                self.entry = name
        if self.entry is None and self.comps:
            self.entry = next(iter(self.comps))

    # ------------------------------------------------------------------
    def cost(self, name: Optional[str] = None) -> Cost:
        name = name or self.entry
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # break cycles
        comp = self.comps.get(name)
        total = Cost()
        if comp is not None:
            for line in comp.lines:
                total.add(self._line_cost(comp, line))
        self._memo[name] = total
        return total

    # ------------------------------------------------------------------
    def _is_bf16_logical(self, comp: _Computation, operands_text: str) -> bool:
        """True when the collective's f32 operand is a convert of a bf16
        value (CPU float-normalization artifact); on TPU it moves bf16."""
        args = operands_text.split(")", 1)[0]
        for nm in _OPERANDS.findall(args):
            d = comp.defs.get(nm)
            if not d:
                continue
            if "convert" not in d:
                continue
            m = _parse_instr(d)
            if not m:
                continue
            inner = m[3].split(")", 1)[0]
            for nm2 in _OPERANDS.findall(inner):
                if "bf16" in comp.shapes.get(nm2, ""):
                    return True
        return False

    def _operand_bytes(self, comp: _Computation, operands_text: str) -> int:
        total = 0
        # strip annotations: operands live before the first "),"
        args = operands_text.split(")", 1)[0]
        for nm in _OPERANDS.findall(args):
            total += _bytes_of(comp.shapes.get(nm, ""))
        return total

    def _dot_flops(self, comp: _Computation, result_shape: str, rest: str) -> float:
        res_elems = _elems_of(result_shape)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
        args = rest.split(")", 1)[0]
        names = _OPERANDS.findall(args)
        k = 1
        if m and names:
            lhs_shape = _shapes_in(comp.shapes.get(names[0], ""))
            if lhs_shape:
                dims = lhs_shape[0][1]
                for idx in m.group(1).split(","):
                    if idx.strip():
                        i = int(idx)
                        if i < len(dims):
                            k *= dims[i]
        return 2.0 * res_elems * k

    def _trip_count(self, line: str, cond_name: Optional[str]) -> Optional[int]:
        m = _TRIP_BC.search(line)
        if m:
            return int(m.group(1))
        cond = self.comps.get(cond_name or "")
        if cond is None:
            return None
        consts: Dict[str, int] = {}
        for ln in cond.lines:
            mc = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)", ln)
            if mc:
                consts[mc.group(1)] = int(mc.group(2))
        for ln in cond.lines:
            if "compare(" in ln or "fusion(" in ln:
                for nm in _OPERANDS.findall(ln.split(")", 1)[0]):
                    if nm in consts:
                        return consts[nm]
        return None

    # ------------------------------------------------------------------
    def _line_cost(self, comp: _Computation, line: str) -> Cost:
        m = _parse_instr(line)
        if not m:
            return Cost()
        _, result_shape, opcode, rest = m
        c = Cost()

        if opcode in _FREE_OPS:
            return c

        base = opcode
        for suffix in ("-start", "-done", "-update"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]

        if base in _COLLECTIVES:
            if not opcode.endswith("-done"):
                b = _bytes_of(result_shape)
                c.coll_bytes += b
                c.coll_bytes_tpu += b // 2 if self._is_bf16_logical(comp, rest) else b
                c.coll_by_kind[base] = c.coll_by_kind.get(base, 0.0) + b
                c.bytes += b + self._operand_bytes(comp, rest)
            return c

        if opcode in ("dot", "convolution"):
            c.flops += self._dot_flops(comp, result_shape, rest)
            c.bytes += _bytes_of(result_shape) + self._operand_bytes(comp, rest)
            return c

        if opcode == "while":
            calls = dict(re.findall(r"(body|condition)=%?([\w.\-]+)", line))
            trips = self._trip_count(line, calls.get("condition"))
            inner = self.cost(calls.get("body")) if calls.get("body") else Cost()
            if trips is None:
                c.unknown_loops += 1
                trips = 1
            c.add(inner, trips)
            return c

        # Data-movement ops that touch only a SLICE of their big operand:
        # counting the full operand would charge the whole scan-stacked
        # parameter tensor on every loop iteration.
        if opcode in ("dynamic-slice", "slice", "gather"):
            c.bytes += 2 * _bytes_of(result_shape)  # read slice + write
            return c
        if opcode in ("dynamic-update-slice", "scatter"):
            args = rest.split(")", 1)[0]
            names = _OPERANDS.findall(args)
            upd_idx = 1 if opcode == "dynamic-update-slice" else 2
            upd = comp.shapes.get(names[upd_idx], "") if len(names) > upd_idx else ""
            c.bytes += 2 * _bytes_of(upd) if upd else _bytes_of(result_shape)
            if opcode == "scatter":
                mcalls = re.search(r"to_apply=%?([\w.\-]+)", line)
                if mcalls:
                    c.flops += self.cost(mcalls.group(1)).flops
            return c

        if opcode == "conditional":
            mbr = re.search(r"branch_computations=\{([^}]*)\}", line)
            branches = []
            if mbr:
                branches = [b.strip().lstrip("%") for b in mbr.group(1).split(",")]
            else:
                branches = re.findall(r"(?:true|false)_computation=%?([\w.\-]+)", line)
            costs = [self.cost(b) for b in branches]
            if costs:
                c.add(max(costs, key=lambda x: x.flops + x.bytes))
            return c

        # ops that call sub-computations (fusion bodies hold the real math).
        # A fusion is ONE kernel: its internal intermediates never touch
        # HBM, so take FLOPs (and any collectives) from the body but count
        # bytes only at the fusion boundary (operands + result).
        mcalls = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
        if mcalls:
            inner = self.cost(mcalls.group(1))
            c.flops += inner.flops
            c.coll_bytes += inner.coll_bytes
            for k, v in inner.coll_by_kind.items():
                c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
            c.unknown_loops += inner.unknown_loops
        c.bytes += _bytes_of(result_shape) + self._operand_bytes(comp, rest)
        if opcode in _ELEMWISE_FLOP_OPS:
            c.flops += _elems_of(result_shape)
        return c


def analyze(hlo_text: str) -> Dict[str, object]:
    hc = HloCost(hlo_text)
    c = hc.cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.coll_bytes,
        "collective_bytes_tpu": c.coll_bytes_tpu,
        "collectives_by_kind": dict(c.coll_by_kind),
        "unknown_trip_loops": c.unknown_loops,
    }
