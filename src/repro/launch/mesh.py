"""Production mesh definitions (TPU v5e target).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — pure DP
across the "pod" axis (the DropCompute All-Reduce domain spans pods).

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_dev_mesh(n_devices: int | None = None, model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU tests / laptops)."""
    n = n_devices or len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"), axis_types=_auto(2)
    )


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
HW = {
    "name": "tpu_v5e",
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bandwidth": 819e9,  # B/s
    "ici_link_bandwidth": 50e9,  # B/s per link
}
