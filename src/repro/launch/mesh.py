"""Deprecated compatibility shim — mesh construction lives in
``repro.dist.mesh``.

Importing this module works but warns: every in-repo caller has been
migrated to ``repro.dist`` (PR 2 moved the implementation; this PR turned
the silent re-export into a ``DeprecationWarning``), and the shim will be
dropped once external callers have had a release to follow.
"""
import warnings

warnings.warn(
    "repro.launch.mesh is deprecated; import from repro.dist.mesh "
    "(or the repro.dist package) instead",
    DeprecationWarning,
    stacklevel=2,
)

from ..dist.mesh import (  # noqa: F401,E402
    HW,
    axes_size,
    axis_types_kwargs,
    dp_axes,
    dp_size,
    make_dev_mesh,
    make_mesh,
    make_production_mesh,
    tp_size,
)

__all__ = [
    "HW",
    "axes_size",
    "axis_types_kwargs",
    "dp_axes",
    "dp_size",
    "make_dev_mesh",
    "make_mesh",
    "make_production_mesh",
    "tp_size",
]
