"""Compatibility shim — mesh construction moved to ``repro.dist.mesh``.

Kept so existing imports (``repro.launch.mesh.make_dev_mesh`` etc.)
continue to work; new code should import from ``repro.dist``.
"""
from ..dist.mesh import (  # noqa: F401
    HW,
    axes_size,
    axis_types_kwargs,
    dp_axes,
    dp_size,
    make_dev_mesh,
    make_mesh,
    make_production_mesh,
    tp_size,
)

__all__ = [
    "HW",
    "axes_size",
    "axis_types_kwargs",
    "dp_axes",
    "dp_size",
    "make_dev_mesh",
    "make_mesh",
    "make_production_mesh",
    "tp_size",
]
