"""SPMD step functions + abstract input specs for every (arch x shape).

``make_train_step`` builds the full DropCompute training step: scan over
M micro-batches, per-(worker, microbatch) drop mask applied as example
weights, global weighted-mean gradient (the All-Reduce of eq. 1 falls out
of pjit), clip, optimizer update.

``make_serve_step`` builds the one-token decode step over a pre-allocated
KV/state cache (decode_32k, long_500k shapes).

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for every model input.

The step builders here are *pure*: they return plain functions.  Sharded,
jitted, donation-aware packaging — in/out shardings from the path rules,
abstract inputs for lowering — is ``repro.dist.Distribution``'s job
(``dist.train_step`` / ``dist.prefill_step`` / ``dist.serve_step``);
``make_train_step`` accepts a ``Distribution`` in place of an explicit
worker count so callers never thread ``mesh``/``n_workers`` by hand.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.dropcompute import DropConfig, drop_mask
from ..dist import mesh as mesh_lib
from ..dist.sharding import _fit_spec, batch_spec
from ..models import ModelConfig, InputShape, decode_step, init_decode_cache, init_params, loss_fn
from ..models import model as model_lib
from ..optim import apply_updates, clip_by_global_norm, make as make_opt

PyTree = Any


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def _as_mesh(mesh_or_dist):
    """Accept a Mesh or a ``Distribution`` wherever a mesh is expected."""
    return getattr(mesh_or_dist, "mesh", mesh_or_dist)


def dp_size(mesh) -> int:
    return mesh_lib.dp_size(_as_mesh(mesh))


def input_specs(
    cfg: ModelConfig, shape: InputShape, mesh=None, n_workers: Optional[int] = None
) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one workload shape (no allocation)."""
    mesh = _as_mesh(mesh)
    b, s = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct

    if shape.mode == "train":
        text = s - cfg.prefix_len if cfg.prefix_len else s
        batch = {"tokens": sds((b, text), i32), "weights": sds((b, text), f32)}
        if cfg.prefix_len:
            batch["prefix"] = sds((b, cfg.prefix_len, cfg.d_model), cfg.compute_dtype)
        if cfg.is_encdec:
            batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
        w = n_workers or (dp_size(mesh) if mesh is not None else 1)
        specs = {
            "batch": batch,
            "latencies": sds((w, shape.microbatches), f32),
        }
        return specs

    if shape.mode == "prefill":
        text = s - cfg.prefix_len if cfg.prefix_len else s
        batch = {"tokens": sds((b, text), i32)}
        if cfg.prefix_len:
            batch["prefix"] = sds((b, cfg.prefix_len, cfg.d_model), cfg.compute_dtype)
        if cfg.is_encdec:
            batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
        return {"batch": batch}

    # decode: one new token against a seq_len cache
    return {
        "token": sds((b, 1), i32),
        "pos": sds((), i32),
    }


def abstract_params(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(cfg: ModelConfig, opt, params_abs: PyTree) -> PyTree:
    return jax.eval_shape(opt.init, params_abs)


def abstract_cache(cfg: ModelConfig, shape: InputShape) -> PyTree:
    def build():
        params = init_params(jax.random.PRNGKey(0), cfg)
        enc_out = (
            jnp.zeros((shape.global_batch, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
            if cfg.is_encdec
            else None
        )
        return init_decode_cache(params, cfg, shape.global_batch, shape.seq_len, enc_out)

    return jax.eval_shape(build)


# ---------------------------------------------------------------------------
# Train step (DropCompute in-graph, SPMD)
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    shape: InputShape,
    drop: DropConfig,
    n_workers: Optional[int] = None,
    optimizer: str = "adamw",
    lr: float = 1e-4,
    clip_norm: float = 1.0,
    moe_impl: str = "sort",
    state_dtype=jnp.float32,
    accum_dtype=jnp.float32,
    cast_params_once: bool = False,
    weight_decay: Optional[float] = None,
    dist=None,
):
    """Returns (opt, step_fn(params, opt_state, batch, latencies)).

    ``n_workers`` (the DropCompute worker count W) may be given explicitly
    or derived from ``dist`` (a ``repro.dist.Distribution``): one virtual
    worker per data shard.  Use ``dist.train_step(...)`` for the jitted,
    sharded version of this step.

    ``state_dtype``/``accum_dtype`` let >100B models halve their Adam
    moments / gradient-accumulator footprint (bf16) on 16 GB chips.

    ``cast_params_once``: cast fp32 params to the compute dtype ONCE,
    outside the micro-batch scan, so per-layer FSDP all-gathers move bf16
    instead of f32 (halves gather volume; gathers repeat every micro-batch
    + remat recompute).  Gradients are then computed w.r.t. the bf16 copy
    and accumulated in ``accum_dtype`` — a §Perf hillclimb lever.
    """
    if n_workers is None:
        if dist is None:
            raise TypeError("make_train_step needs n_workers= or dist=")
        n_workers = dist.dp_size
    opt_kw = {} if weight_decay is None else {"weight_decay": weight_decay}
    if optimizer == "adamw":
        opt_kw["state_dtype"] = state_dtype
    opt = make_opt(optimizer, lr, **opt_kw)
    m = shape.microbatches
    b = shape.global_batch
    assert b % (n_workers * m) == 0, (b, n_workers, m)
    mbw = b // (n_workers * m)  # rows per (worker, microbatch)

    def grad_one(params, mb, ex_w):
        def lsum(p):
            batch = dict(mb)
            batch["weights"] = batch["weights"] * ex_w[:, None]
            return loss_fn(p, cfg, batch, moe_impl=moe_impl)

        (loss_sum, w_sum), grads = jax.value_and_grad(lambda p: lsum(p), has_aux=True)(params)
        return grads, loss_sum, w_sum

    def step(params, opt_state, batch, latencies):
        # --- Algorithm 1: drop mask from per-(worker, microbatch) latency ---
        mask = drop_mask(latencies, drop.tau, drop.min_microbatches)  # (W, M)
        if not drop.enabled:
            mask = jnp.ones_like(mask)

        # Reorder the global batch so axis0 = microbatch index: rows of
        # worker w stay in w's shard ((W, M, mbw) -> (M, W*mbw)).
        def to_micro(x):
            xs = x.reshape(n_workers, m, mbw, *x.shape[1:])
            return jnp.moveaxis(xs, 1, 0).reshape(m, n_workers * mbw, *x.shape[1:])

        micro = jax.tree.map(to_micro, batch)
        ex_w = jnp.repeat(mask.T, mbw, axis=1)  # (M, W*mbw)

        if cast_params_once:
            params_use = jax.tree.map(
                lambda p: p.astype(cfg.compute_dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p,
                params,
            )
        else:
            params_use = params

        def body(carry, xs):
            g_acc, l_acc, w_acc = carry
            mb, w_row = xs
            g, l, w = grad_one(params_use, mb, w_row)
            g_acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype), g_acc, g)
            return (g_acc, l_acc + l, w_acc + w), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (g_sum, loss_sum, w_sum), _ = jax.lax.scan(
            body, (g0, jnp.zeros(()), jnp.zeros(())), (micro, ex_w)
        )

        # --- eq. (1) normalization (nominal vs computed, §B.2.2) ---
        if drop.normalize == "computed":
            denom = jnp.maximum(w_sum, 1.0)
        else:
            per_mb = w_sum / jnp.maximum(jnp.sum(mask), 1.0)
            denom = jnp.maximum(per_mb * m * n_workers, 1.0)
        grads = jax.tree.map(lambda g: g / denom, g_sum)

        if clip_norm > 0:
            grads = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {
            "loss": loss_sum / jnp.maximum(w_sum, 1.0),
            "completed_fraction": jnp.mean(mask),
            "computed_weight": w_sum,
        }
        return params, opt_state, metrics

    return opt, step


# ---------------------------------------------------------------------------
# Prefill / decode steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, moe_impl: str = "sort"):
    def step(params, batch):
        # logits only for the LAST position — full-sequence logits at 32k x
        # 262k vocab would be hundreds of GB/device.
        x, _ = model_lib.forward_features(params, cfg, batch, moe_impl=moe_impl)
        from ..models import layers as L

        logits = L.unembed(params["embed"], x[:, -1:], cfg)
        return jnp.argmax(logits[:, -1], axis=-1)

    return step


def make_serve_step(cfg: ModelConfig, moe_impl: str = "dense"):
    def step(params, cache, token, pos):
        logits, cache = decode_step(params, cfg, cache, token, pos, moe_impl=moe_impl)
        next_tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(jnp.int32)
        return next_tok, cache

    return step


# ---------------------------------------------------------------------------
# Input shardings
# ---------------------------------------------------------------------------


def batch_shardings(
    cfg: ModelConfig, shape: InputShape, mesh, n_workers: Optional[int] = None
) -> PyTree:
    mesh = _as_mesh(mesh)
    bs = batch_spec(mesh, shape.global_batch)

    def leaf_spec(x):
        return NamedSharding(mesh, P(bs[0], *([None] * (len(x.shape) - 1))))

    specs = input_specs(cfg, shape, mesh, n_workers=n_workers)
    out: Dict[str, Any] = {}
    if "batch" in specs:
        out["batch"] = jax.tree.map(leaf_spec, specs["batch"])
    if "latencies" in specs:
        # (W, M): W need not be divisible by the dp size even when the
        # global batch is — fit the spec to the latencies' own shape
        lat_shape = specs["latencies"].shape
        out["latencies"] = NamedSharding(mesh, _fit_spec(lat_shape, (bs[0], None), mesh))
    if "token" in specs:
        out["token"] = NamedSharding(mesh, P(bs[0], None))
        out["pos"] = NamedSharding(mesh, P())
    return out
