import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 host placeholder devices, jits the real
train/prefill/serve step with the real sharding rules, and records

  * memory_analysis()   — per-device bytes (proves it fits 16 GB v5e HBM),
  * cost_analysis()     — HLO FLOPs / bytes for the roofline terms,
  * the collective schedule parsed from the compiled HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute bytes).

Results are cached as JSON under benchmarks/results/dryrun/ so reruns
only compile what changed.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, get_config
from repro.launch import hlo_cost
from repro.core.dropcompute import DropConfig
from repro.dist import HW, Distribution
from repro.launch import steps as S
from repro.models import INPUT_SHAPES

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# long_500k needs sub-quadratic attention: only SSM / hybrid / SWA archs
# run it (see DESIGN.md §long-context).  Encoder-only (bert) has no decode.
LONG_CONTEXT_ARCHS = {"mamba2_130m", "recurrentgemma_2b", "mixtral_8x22b", "gemma3_27b"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        key = dt[:4] if dt.startswith("f8") else dt
        total += n * _DTYPE_BYTES.get(key, 4)
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the compiled HLO."""
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        shapes, opcode = m.group(1), m.group(2)
        base = opcode.rstrip("-start").rstrip("-done") if opcode.endswith(("-start", "-done")) else opcode
        for c in _COLLECTIVES:
            if base == c or opcode == c or opcode == c + "-start":
                if opcode.endswith("-done"):
                    break  # avoid double counting start/done pairs
                out[c]["count"] += 1
                out[c]["bytes"] += _shape_bytes(shapes)
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def lower_combo(
    arch: str,
    shape_name: str,
    dist: Distribution,
    multi_pod: bool,
    drop_tau: float = float("inf"),
    cast_once: bool = False,
    microbatches: int = 0,
    lower_only: bool = False,
):
    """Lower (+ compile, unless ``lower_only``) one (arch, shape, mesh)
    through the ``repro.dist`` step builders. Returns result dict.

    ``cast_once``/``microbatches`` are §Perf hillclimb knobs.
    """
    import dataclasses

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train" and get_config(arch).param_count() > 50e9 and not multi_pod:
        # single-pod giants: 16 accumulations (paper uses 12) halve the
        # per-micro-batch activation footprint
        shape = dataclasses.replace(shape, microbatches=16)
    if microbatches and shape.mode == "train":
        shape = dataclasses.replace(shape, microbatches=microbatches)

    t0 = time.time()
    moe_impl = "spmd" if cfg.n_experts > 0 else "sort"
    # >50B models: bf16 Adam moments + bf16 grad accumulators — required
    # to fit 16 GB/chip state bytes at 235B params / 256 chips (see
    # EXPERIMENTS.md §Dry-run notes).
    big = cfg.param_count() > 50e9
    dt = jnp.bfloat16 if big else jnp.float32
    if shape.mode == "train":
        drop = DropConfig(enabled=True, tau=drop_tau, normalize="computed")
        bundle = dist.train_step(
            cfg, shape, drop, moe_impl=moe_impl,
            state_dtype=dt, accum_dtype=dt, cast_params_once=cast_once,
        )
    elif shape.mode == "prefill":
        bundle = dist.prefill_step(cfg, shape, moe_impl=moe_impl)
    else:  # decode
        bundle = dist.serve_step(cfg, shape)
    lowered = bundle.lower()
    t_lower = time.time() - t0

    if lower_only:
        return {
            "arch": arch,
            "shape": shape_name,
            "mesh": "x".join(str(s) for s in dist.mesh.devices.shape),
            "mode": shape.mode,
            "lower_s": round(t_lower, 1),
            "lower_only": True,
        }

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4 returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    walked = hlo_cost.analyze(hlo)  # trip-count-aware (scans multiplied)

    mesh = dist.mesh
    n_chips = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "n_chips": int(n_chips),
        "mode": shape.mode,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "cost": {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and "{" not in k
        },
        # trip-count-aware walk of the compiled HLO (per-device numbers):
        "walked": walked,
        "collectives": coll,
        "param_count": get_config(arch).param_count(),
        "active_param_count": get_config(arch).active_param_count(),
        "hw": HW,
    }
    return result


def combos(include_long=True):
    for arch in ARCHITECTURES:
        cfg = get_config(arch)
        for shape_name in INPUT_SHAPES:
            if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--lower-only", action="store_true",
                    help="stop after lowering (no XLA compile) — CI smoke")
    ap.add_argument("--tag", default="", help="suffix for result files (perf iterations)")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    if args.all:
        todo = list(combos())
    else:
        assert args.arch and args.shape, "--arch and --shape, or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for multi_pod in meshes:
        dist = Distribution.production(multi_pod=multi_pod)
        mesh_tag = "2x16x16" if multi_pod else "16x16"
        for arch, shape_name in todo:
            name = f"{arch}_{shape_name}_{mesh_tag}{args.tag}.json"
            out_path = RESULTS_DIR / name
            if out_path.exists() and not args.force and not args.lower_only:
                print(f"[skip] {name} (cached)")
                continue
            print(f"[run ] {arch} x {shape_name} on {mesh_tag} ...", flush=True)
            try:
                res = lower_combo(arch, shape_name, dist, multi_pod,
                                  lower_only=args.lower_only)
                if args.lower_only:
                    print(f"  ok: lowered in {res['lower_s']}s (no compile)")
                    continue
                out_path.write_text(json.dumps(res, indent=1))
                per_dev = res["memory"]
                total_fit = (per_dev["output_bytes"] + per_dev["temp_bytes"] + per_dev["argument_bytes"])
                print(
                    f"  ok: compile {res['compile_s']}s, "
                    f"mem/dev {total_fit/2**30:.2f} GiB, "
                    f"flops {res['walked']['flops']:.3e}, "
                    f"coll {res['walked']['collective_bytes']/2**20:.1f} MiB, "
                    f"unkloops {res['walked']['unknown_trip_loops']}"
                )
            except Exception as e:
                failures.append((arch, shape_name, mesh_tag, repr(e)))
                print(f"  FAIL: {e!r}")
                traceback.print_exc(limit=3)

    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll dry-run combos %s successfully."
          % ("lowered" if args.lower_only else "compiled"))


if __name__ == "__main__":
    main()
