"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 20 --drop-compute --auto-threshold

Selects an architecture from the registry (``--arch``, full or ``--smoke``
reduced config), builds the data pipeline and the DropCompute trainer, and
runs.  On a multi-device system pass ``--mesh`` data,model dims (e.g.
``--mesh 4,2``, or ``--mesh 2,16,16`` for pod,data,model) to run the
sharded SPMD step via the ``repro.dist`` sharding rules; without it the
virtual-worker simulation path runs on a single device (the
physical-cluster behaviour is exercised by the dry-run,
``repro.launch.dryrun``).
"""
import argparse

import numpy as np

from repro.configs import ARCHITECTURES, PAPER_MODELS, get_config, get_smoke_config
from repro.core import DropConfig, LatencyModel, NoiseModel
from repro.data import DataConfig
from repro.dist import Distribution
from repro.train import TrainConfig, train
from repro.train.resilience import SCENARIOS, make_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {ARCHITECTURES + PAPER_MODELS}")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced same-family config (CPU-trainable)")
    ap.add_argument("--full-config", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "lamb", "lans", "sgd"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--drop-compute", action="store_true")
    ap.add_argument("--tau", type=float, default=float("inf"))
    ap.add_argument("--auto-threshold", action="store_true")
    ap.add_argument("--normalize", default="computed", choices=["computed", "nominal"])
    ap.add_argument("--noise", default="paper_lognormal")
    ap.add_argument("--tc", type=float, default=0.5)
    ap.add_argument("--faults", default="", choices=[""] + sorted(SCENARIOS),
                    help="seeded resilience fault scenario layered over the "
                         "latency model (pareto/lognormal/badnode/stall/none)")
    ap.add_argument("--fault-onset", type=int, default=None,
                    help="step where mid-run faults (ramp/badnode) begin")
    ap.add_argument("--online-tau", action="store_true",
                    help="re-estimate tau* online from rolling telemetry "
                         "(replaces the one-shot --auto-threshold calibration)")
    ap.add_argument("--inject-real-delays", action="store_true",
                    help="sleep the injected fault delays around the real "
                         "step (physical compute variance)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", default="",
                    help="checkpoint dir to resume from (params, opt state "
                         "AND the adapted tau-controller state)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="mesh dims: 'data,model' (e.g. 4,2) or "
                         "'pod,data,model' (e.g. 2,16,16); empty = "
                         "single-device virtual-worker path")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dist = Distribution.from_spec(args.mesh) if args.mesh else None
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family} pattern={cfg.layer_pattern}"
          + (f" mesh={'x'.join(map(str, dist.mesh.devices.shape))}" if dist else ""))

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_size=args.batch, strategy="pack", seed=args.seed)
    latency = LatencyModel(base=0.45, noise=NoiseModel(kind=args.noise))
    if args.faults:
        latency = make_scenario(args.faults, base=latency, seed=args.seed,
                                onset=args.fault_onset)
    tcfg = TrainConfig(
        steps=args.steps, n_workers=args.workers, microbatches=args.microbatches,
        optimizer=args.optimizer, lr=args.lr,
        drop=DropConfig(enabled=args.drop_compute, tau=args.tau, normalize=args.normalize),
        auto_threshold=args.auto_threshold and not args.online_tau,
        calibration_steps=min(20, args.steps // 2),
        online_tau=args.online_tau, inject_real_delays=args.inject_real_delays,
        latency=latency, tc=args.tc, seed=args.seed, mesh=dist,
        ckpt_dir=args.ckpt or None, ckpt_every=50 if args.ckpt else 0,
        resume_from=args.resume or None,
    )
    r = train(cfg, data, tcfg)
    print(f"[train] loss {r.losses[0]:.3f} -> {r.losses[-1]:.3f}  "
          f"sim time {r.metrics['total_sim_time']:.0f}s  "
          f"drop {np.mean(r.drop_fractions):.1%}  tau={r.tau}")
    if len(r.tau_trajectory) > 1:
        print("[train] tau trajectory: "
              + " -> ".join(f"{s}:{t:.2f}" if np.isfinite(t) else f"{s}:inf"
                            for s, t in r.tau_trajectory))


if __name__ == "__main__":
    main()
