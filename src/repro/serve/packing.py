"""Token-packed step layout: flatten granted (slot, position) tokens.

The dense engine step computes a full ``(B, chunk_size)`` shape no matter
how many tokens the budget actually granted, so its wall time is bounded
but not *proportional* to the budget.  This module is the layout pass of
the token-packed step program (vLLM-style flattened batch): every token
granted this iteration — one per decode slot, up to a chunk per prefill
slot — is packed into a fixed-capacity ``(capacity,)`` vector together
with its cache-slot id and absolute position.  Granted tokens alone then
determine the compute of the packed model path
(``repro.models.model.packed_prefill``), which is what turns the per-step
token budget (the serving ``tau``) into a genuine per-step compute bound.

Invariants (property-tested in ``tests/test_property.py``):

* at most ``capacity`` entries; ``pack_step`` raises ``ValueError`` on
  overflow rather than silently truncating;
* scatter destinations ``(slot, position)`` are unique — the packed KV
  write is race-free;
* positions are contiguous per slot, starting at the slot's write
  cursor;
* every granted token appears exactly once, in grant order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

#: slot id marking padding entries; scatter drops them (out-of-range write
#: position) and the packed attention masks them out.
PAD_SLOT = -1

#: Grant = (slot index, first absolute position, tokens to consume).
Grant = Tuple[int, int, Sequence[int]]


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """One engine iteration's granted tokens, flattened.

    Arrays all have length ``capacity``; entries past ``n_tokens`` are
    padding (``slot_ids == PAD_SLOT``, ``positions == 0``, ``tokens == 0``).
    """

    tokens: np.ndarray  # (capacity,) int32
    slot_ids: np.ndarray  # (capacity,) int32; PAD_SLOT on padding
    positions: np.ndarray  # (capacity,) int32 absolute cache positions
    #: (n_segments + 1,) packed offset of each grant's first token —
    #: diagnostic/telemetry only; the model path derives segment
    #: isolation from slot_ids alone (per-token slot gather)
    segment_starts: np.ndarray
    #: slot -> (first packed index, token count) of its grant — the
    #: speculative verifier reads every granted column; a plain decode
    #: consumer reads the span's last (``start + count - 1``)
    spans: Dict[int, Tuple[int, int]]
    #: (capacity,) int32 per-token *output index* — which generated token
    #: of its request each entry's next-token prediction would be, the
    #: ``fold_in`` data of the sampler's per-position PRNG key
    #: (``serve.sampling``).  Prefill entries before a request's final
    #: prompt token predict tokens that are never emitted; their indices
    #: are clamped to 0 (a key is still derived, the sample discarded).
    #: Padding entries are 0.  All zeros unless ``pack_step`` was given
    #: ``out_base``.
    out_idx: np.ndarray
    n_tokens: int
    capacity: int


def packed_capacity(batch_slots: int, chunk_size: int, token_budget,
                    draft_k: int = 0) -> int:
    """Compiled packed-program length for an engine configuration.

    The scheduler can exceed ``token_budget`` in exactly two ways: decode
    slots are unconditional (up to ``batch_slots`` tokens even when the
    budget is smaller) and the starvation guard grants one extra prefill
    token when decodes alone exhaust the budget — hence
    ``max(batch_slots, token_budget) + 1``.  Speculative draft tokens
    (``draft_k`` per decode slot) are *not* unconditional — they compete
    under the budget like prefill chunks — so they leave the budgeted
    bound unchanged.  With no budget every prefilling slot may take a
    full chunk and every decode slot a full verify window:
    ``batch_slots * max(chunk_size, draft_k + 1)``.
    """
    if token_budget is None:
        return batch_slots * max(chunk_size, draft_k + 1)
    return max(batch_slots, token_budget) + 1


def pack_step(grants: Sequence[Grant], capacity: int,
              out_base: "Dict[int, int] | None" = None) -> PackedLayout:
    """Flatten this iteration's grants into a fixed-capacity layout.

    ``grants`` is the scheduler's output: for each active slot, the slot
    index, the slot's current write cursor (first absolute position), and
    the tokens it consumes this step (one for decode, up to a chunk for
    prefill).  Zero-token grants are allowed and occupy no entries.

    ``out_base`` optionally maps slot -> the output index of the grant's
    *first* entry's prediction (may be negative mid-prefill, where early
    columns predict nothing that is emitted); entry ``j`` of a grant gets
    ``out_base[slot] + j``, clamped at 0, in ``PackedLayout.out_idx``.
    """
    total = sum(len(toks) for _, _, toks in grants)
    if total > capacity:
        raise ValueError(
            f"packed layout overflow: {total} granted tokens > capacity "
            f"{capacity}; the scheduler and packed_capacity() disagree"
        )
    tokens = np.zeros((capacity,), np.int32)
    slot_ids = np.full((capacity,), PAD_SLOT, np.int32)
    positions = np.zeros((capacity,), np.int32)
    out_idx = np.zeros((capacity,), np.int32)
    starts: List[int] = [0]
    spans: Dict[int, Tuple[int, int]] = {}
    cursor = 0
    for slot, pos0, toks in grants:
        m = len(toks)
        if m == 0:
            continue
        tokens[cursor : cursor + m] = toks
        slot_ids[cursor : cursor + m] = slot
        positions[cursor : cursor + m] = np.arange(pos0, pos0 + m)
        if out_base is not None:
            base = out_base.get(slot, 0)
            out_idx[cursor : cursor + m] = np.maximum(
                base + np.arange(m), 0
            )
        spans[slot] = (cursor, m)
        cursor += m
        starts.append(cursor)
    return PackedLayout(
        tokens=tokens,
        slot_ids=slot_ids,
        positions=positions,
        segment_starts=np.asarray(starts, np.int32),
        spans=spans,
        out_idx=out_idx,
        n_tokens=total,
        capacity=capacity,
    )
