"""Host-side paged-KV bookkeeping: page allocator, block tables, prefix cache.

The device side of the paged layout (``repro.serve.kv``) is a flat pool of
``(num_pages, page_size)`` KV rows per layer; this module owns everything
that decides *which* pool row a ``(slot, position)`` pair maps to:

* a free-list **page allocator** with per-page reference counts;
* one **block table** per cache slot (logical block ``pos // page_size``
  -> physical page), materialized for the device as a dense
  ``(num_slots, num_blocks)`` int32 array with ``num_pages`` as the
  "unallocated" sentinel (scatter-dropped / mask-hidden on device);
* a **prefix cache**: every fully-written prompt page is registered under
  a chain key (the exact token tuple chain from position 0), so a later
  request whose prompt starts with the same tokens maps the existing
  pages instead of recomputing their KV — prefix sharing;
* **copy-on-write**: a page referenced by more than one slot is never
  written in place; ``prepare_write`` allocates a private copy and
  returns ``(src, dst)`` ops for the device-side page copy (the
  ``fork`` path — engine-driven prefix sharing only ever shares full,
  finished pages, so it never triggers COW).

Reservation accounting makes admission safe: ``admit`` only succeeds when
the pool can cover the request's worst case (prompt + max_new tokens,
minus pages it can share), so decode — which is unconditional in the
scheduler — can never deadlock on an empty pool mid-request.

Pages whose refcount drops to zero but that are registered in the prefix
cache are *retained* (a reclaimable "cached" tier, evicted LRU when the
free list runs dry): a request arriving after its prefix-mate finished
still shares its pages.  Invariants are property-tested in
``tests/test_serve_paged.py`` via ``check_invariants``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class PageError(RuntimeError):
    """Host-side paged-KV bookkeeping violation."""


class OutOfPages(PageError):
    """The pool has no free or reclaimable page left.

    Unreachable through the scheduler (admission reserves worst-case
    pages); reachable through unreserved paths (``fork``/COW) on an
    undersized pool.
    """


#: Interned chain-key id.  The chain key of block ``b`` is logically the
#: whole token prefix ``prompt[:(b+1)*page_size]``; comparing that
#: directly would make probing quadratic in prompt length, so chains are
#: *interned*: ``_key_ids`` maps ``(parent_id, block_tokens)`` to a small
#: int, and by induction two chains get the same id iff their full token
#: prefixes are identical — exact equality (no hash-collision false
#: sharing) at O(page_size) per lookup.
ChainKey = int

#: parent id of a chain's first block
ROOT_KEY: ChainKey = 0


class PagedTables:
    """Block tables + ref-counted page pool + prefix cache for one engine."""

    def __init__(self, num_slots: int, num_blocks: int, num_pages: int, page_size: int):
        if min(num_slots, num_blocks, num_pages, page_size) < 1:
            raise PageError(  # typed, not assert: must survive python -O
                f"PagedTables sizes must be >= 1: slots={num_slots}, "
                f"blocks={num_blocks}, pages={num_pages}, page_size={page_size}"
            )
        self.num_slots = num_slots
        self.num_blocks = num_blocks
        self.num_pages = num_pages
        self.page_size = page_size
        self.tables: List[List[int]] = [[] for _ in range(num_slots)]
        self.ref = [0] * num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))  # pop() -> 0, 1, ...
        self._cached: "OrderedDict[int, ChainKey]" = OrderedDict()  # ref==0, retained
        self._touched: set = set()  # allocated since the last rebaseline
        self._prefix: Dict[ChainKey, int] = {}  # chain-key id -> page
        self._page_key: Dict[int, ChainKey] = {}  # registered page -> chain-key id
        self._reserved = [0] * num_slots
        # chain-key interning: (parent id, block token tuple) -> id.  Ids
        # are append-only — they stay valid across eviction (an evicted
        # chain re-registers under its old id); the table is bounded by
        # distinct (parent, block) pairs ever *registered*, since probes
        # look up without interning.
        self._key_ids: Dict[Tuple[ChainKey, Tuple[int, ...]], ChainKey] = {}
        self._next_key = ROOT_KEY + 1
        # per-slot chain frontier: _chain[slot][b] = chain id of this
        # slot's prompt blocks 0..b — extended incrementally so repeated
        # probes/registrations stay O(new blocks), not O(pos)
        self._chain: List[List[ChainKey]] = [[] for _ in range(num_slots)]

    # -- introspection ------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        return len(self._cached)

    @property
    def used_pages(self) -> int:
        """Pages referenced by at least one slot."""
        return self.num_pages - len(self._free) - len(self._cached)

    @property
    def touched_pages(self) -> int:
        """Pages drawn from the free list since the last
        ``reset_touched`` and still holding content."""
        return len(self._touched)

    def reset_touched(self) -> None:
        """Rebaseline the touched-page counter without dropping live or
        prefix-cached pages: subsequent ``touched_pages`` reads count only
        pages allocated after this call (a warmed-up engine's measured run
        records its own page traffic, not the warmup's)."""
        self._touched.clear()

    def available(self) -> int:
        """Pages an ``admit`` may still promise without starving existing
        reservations: free + reclaimable, minus outstanding reservations."""
        return len(self._free) + len(self._cached) - sum(self._reserved)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def pages_required(self, prompt_len: int, max_new: int) -> int:
        """Distinct pool pages a request's table references at worst case.
        Prefix sharing avoids *allocating* (and recomputing) shared pages
        but they still occupy the pool, so this is the feasibility bound
        against ``num_pages``."""
        return self.blocks_for(prompt_len + max_new)

    # -- chain-key interning ------------------------------------------------

    def _extend_chain(self, slot: int, prompt: Sequence[int], upto_block: int,
                      intern: bool) -> List[ChainKey]:
        """Extend ``slot``'s cached chain ids through block ``upto_block``
        (exclusive).  ``intern=False`` (probing) stops at the first chain
        never registered — nothing can be shared past it anyway;
        ``intern=True`` (registration) mints new ids."""
        ps = self.page_size
        ids = self._chain[slot]
        while len(ids) < upto_block:
            b = len(ids)
            parent = ids[b - 1] if b else ROOT_KEY
            key = (parent, tuple(prompt[b * ps : (b + 1) * ps]))
            kid = self._key_ids.get(key)
            if kid is None:
                if not intern:
                    break
                kid = self._next_key
                self._next_key += 1
                self._key_ids[key] = kid
            ids.append(kid)
        return ids

    # -- admission / sharing ------------------------------------------------

    def _probe_shared(self, slot: int, prompt: Sequence[int], start_block: int) -> List[int]:
        """Pages the prefix cache can supply for ``prompt`` starting at
        ``start_block``.  At least one prompt token is always left for the
        owner to process (its logits feed the first sampled token), so a
        block is shareable only when it ends strictly before the prompt
        does: ``(b+1)*page_size < len(prompt)``."""
        ps = self.page_size
        last = (len(prompt) - 1) // ps  # first non-shareable block
        ids = self._extend_chain(slot, prompt, last, intern=False)
        pages: List[int] = []
        for b in range(start_block, min(len(ids), last)):
            page = self._prefix.get(ids[b])
            if page is None:
                break
            pages.append(page)
        return pages

    def _map_page(self, slot: int, page: int, consume_reservation: bool) -> None:
        if self.ref[page] == 0:
            del self._cached[page]  # reclaimable -> active
        self.ref[page] += 1
        self.tables[slot].append(page)
        if consume_reservation and self._reserved[slot] > 0:
            self._reserved[slot] -= 1

    def admit(self, slot: int, prompt: Sequence[int], max_new: int) -> Optional[int]:
        """Reserve worst-case pages for a request and map its shareable
        prefix.  Returns the number of prompt tokens covered by shared
        pages (the caller skips prefilling them), or ``None`` when the
        pool cannot guarantee the request — leave it queued."""
        if self.tables[slot]:
            raise PageError(f"slot {slot} still holds pages; free it first")
        total = self.blocks_for(len(prompt) + max_new)
        if total > self.num_blocks:
            raise PageError(
                f"request needs {total} blocks > table capacity {self.num_blocks}"
            )
        if total > self.num_pages:
            # returning None would park this request at the queue head
            # forever (FIFO admission) — fail loudly instead
            raise PageError(
                f"request can never fit: it references {total} distinct "
                f"pages (shared or not), pool has {self.num_pages}"
            )
        self._chain[slot] = []
        shared = self._probe_shared(slot, prompt, 0)
        # shared pages sitting in the reclaimable tier leave it when
        # mapped, so they count against availability like fresh pages
        n_reclaim = sum(1 for p in shared if self.ref[p] == 0)
        needed = total - len(shared)
        if self.available() < needed + n_reclaim:
            return None
        self._reserved[slot] = needed
        for page in shared:
            self._map_page(slot, page, consume_reservation=False)
        return len(shared) * self.page_size

    def probe_shareable(self, prompt: Sequence[int]) -> int:
        """Prompt tokens the prefix cache could supply for ``prompt`` right
        now, without touching any slot state.  Admission uses it to dedup
        *in-flight* prefixes: when an active slot is still prefilling a
        prompt that will publish more shareable pages than this, the new
        request is worth parking until those pages land."""
        ps = self.page_size
        last = (len(prompt) - 1) // ps  # first non-shareable block
        parent, n = ROOT_KEY, 0
        for b in range(last):
            kid = self._key_ids.get((parent, tuple(prompt[b * ps : (b + 1) * ps])))
            if kid is None or kid not in self._prefix:
                break
            parent, n = kid, n + 1
        return n * ps

    def try_share(self, slot: int, prompt: Sequence[int], pos: int) -> int:
        """Map any prefix-cache pages covering ``prompt`` from ``pos`` on
        (mid-prefill sharing: an older request may have finished writing
        these pages since the last step).  Returns tokens covered."""
        ps = self.page_size
        if pos % ps != 0 or len(self.tables[slot]) != pos // ps:
            return 0  # mid-block, or the slot already owns this block
        pages = self._probe_shared(slot, prompt, pos // ps)
        for page in pages:
            self._map_page(slot, page, consume_reservation=True)
        return len(pages) * ps

    # -- writes -------------------------------------------------------------

    def _alloc(self, slot: int, consume_reservation: bool = True) -> int:
        if self._free:
            page = self._free.pop()
        elif self._cached:
            page, key = self._cached.popitem(last=False)  # evict LRU
            del self._prefix[key]
            del self._page_key[page]
        else:
            raise OutOfPages(
                f"page pool exhausted ({self.num_pages} pages, "
                f"{self.used_pages} in use)"
            )
        self.ref[page] = 1
        self._touched.add(page)
        if consume_reservation and self._reserved[slot] > 0:
            self._reserved[slot] -= 1
        return page

    def prepare_write(self, slot: int, start: int, n: int) -> List[Tuple[int, int]]:
        """Make positions ``[start, start + n)`` of ``slot`` writable:
        allocate missing blocks and copy-on-write any block shared with
        another slot.  Returns ``(src, dst)`` page-copy ops the caller
        must apply to the device pool *before* the step's scatter."""
        if n <= 0:
            return []
        ps = self.page_size
        table = self.tables[slot]
        ops: List[Tuple[int, int]] = []
        for b in range(start // ps, (start + n - 1) // ps + 1):
            if b < len(table):
                page = table[b]
                if self.ref[page] > 1:  # shared: never write in place
                    dst = self._alloc(slot, consume_reservation=False)
                    self.ref[page] -= 1
                    table[b] = dst
                    ops.append((page, dst))
            else:
                if b != len(table):
                    raise PageError(
                        f"non-contiguous write: slot {slot} block {b}, "
                        f"table has {len(table)}"
                    )
                table.append(self._alloc(slot))
        return ops

    def register_prompt_pages(self, slot: int, prompt: Sequence[int], upto: int) -> None:
        """Publish ``slot``'s fully-written prompt pages (positions
        ``< upto``) into the prefix cache."""
        ps = self.page_size
        table = self.tables[slot]
        n_full = min(min(upto, len(prompt)) // ps, len(table))
        ids = self._extend_chain(slot, prompt, n_full, intern=True)
        for b in range(n_full):
            page, key = table[b], ids[b]
            if page in self._page_key or key in self._prefix:
                continue  # already published (e.g. a page this slot shared in)
            self._prefix[key] = page
            self._page_key[page] = key

    # -- lifecycle ----------------------------------------------------------

    def _decref(self, page: int) -> None:
        if self.ref[page] <= 0:
            raise PageError(f"double free of page {page}")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            key = self._page_key.get(page)
            if key is not None and self._prefix.get(key) == page:
                self._cached[page] = key  # retain for prefix reuse
            else:
                self._free.append(page)
                self._touched.discard(page)

    def free_slot(self, slot: int) -> None:
        """Release everything ``slot`` holds — the normal-completion path
        and the cancellation reclaim path alike.  Works mid-prefill and
        mid-decode: shared prefix pages survive with their other owners
        (refcount > 0), fully-registered prompt pages drop to the
        reclaimable prefix-cache tier (a cancelled request's prefix KV is
        still valid for future prompts), and the partially written tail
        page — never registered — returns straight to the free list.
        Idempotent on an already-empty slot."""
        for page in self.tables[slot]:
            self._decref(page)
        self.tables[slot] = []
        self._reserved[slot] = 0
        self._chain[slot] = []

    def trim(self, slot: int, n_tokens: int) -> int:
        """Drop every block of ``slot`` wholly past ``n_tokens`` kept
        positions — the paged half of speculative-decoding rollback
        (rejected draft tokens wrote KV into blocks the sequence no longer
        reaches).  The block holding the last kept token stays; dropped
        pages are decref'd (shared pages survive with their other owners,
        prefix-registered pages move to the reclaimable tier) and restored
        to the slot's reservation so availability accounting still covers
        its admitted worst case.  Returns the number of blocks dropped."""
        keep = self.blocks_for(n_tokens)
        table = self.tables[slot]
        if keep >= len(table):
            return 0
        dropped = table[keep:]
        del table[keep:]
        for page in dropped:
            self._decref(page)
        self._reserved[slot] += len(dropped)
        return len(dropped)

    def fork(self, parent: int, child: int) -> None:
        """Share every page of ``parent`` with ``child`` (beam-style fork).
        Writes by either slot to a shared block copy-on-write via
        ``prepare_write``.  Fork bypasses reservation accounting: callers
        must size the pool for the copies they may trigger."""
        if self.tables[child]:
            raise PageError(f"fork target slot {child} is not empty")
        for page in self.tables[parent]:
            self.ref[page] += 1
            self.tables[child].append(page)

    # -- device view --------------------------------------------------------

    def device_tables(self) -> np.ndarray:
        """(num_slots, num_blocks) int32; ``num_pages`` marks unallocated
        blocks (out-of-range: scatter-dropped, gather-masked)."""
        arr = np.full((self.num_slots, self.num_blocks), self.num_pages, np.int32)
        for i, t in enumerate(self.tables):
            if t:
                arr[i, : len(t)] = t
        return arr

    # -- invariants (property-tested) ---------------------------------------

    def check_invariants(self) -> None:
        counts = [0] * self.num_pages
        for t in self.tables:
            for p in t:
                counts[p] += 1
        if counts != list(self.ref):
            raise PageError(f"refcount drift: {self.ref} vs table counts {counts}")
        free, cached = set(self._free), set(self._cached)
        if len(self._free) != len(free):
            raise PageError("duplicate page on the free list")
        if free & cached:
            raise PageError(f"pages both free and cached: {free & cached}")
        active = {p for p, r in enumerate(self.ref) if r > 0}
        if active & (free | cached):
            raise PageError("referenced page on the free/cached lists")
        if len(free) + len(cached) + len(active) != self.num_pages:
            raise PageError(
                f"page conservation violated: {len(free)} free + "
                f"{len(cached)} cached + {len(active)} active != {self.num_pages}"
            )
        for page, key in self._cached.items():
            if self._prefix.get(key) != page:
                raise PageError(f"cached page {page} not in the prefix cache")
        for key, page in self._prefix.items():
            if self._page_key.get(page) != key:
                raise PageError(f"prefix entry {key!r} -> {page} not back-linked")
        if any(r < 0 for r in self._reserved):
            raise PageError("negative reservation")
        if self._touched & free:
            raise PageError(f"touched pages on the free list: {self._touched & free}")
