"""``repro.serve.frontend`` — the asyncio serving front-end.

``ContinuousBatcher`` is a synchronous engine: callers submit, call
``step`` in a loop, and read finished requests off a dict.  That is the
right shape for parity tests and benchmarks, but not for serving — a
server needs requests to *arrive* while the engine is mid-step, tokens
to stream back per request as they are produced, and load beyond the
engine's admission capacity to be shed deliberately instead of crashing
the caller.  :class:`AsyncEngine` wraps one engine with exactly that:

* a **background driver task** owns the engine step loop; each blocking
  ``step`` runs in a thread-pool executor so the event loop keeps
  accepting arrivals and cancellations while the model computes;
* ``submit()`` returns a :class:`RequestStream` — an async iterator
  yielding output tokens as engine steps produce them, plus the
  request's lifecycle event log (queued → admitted → first_token →
  finished / dropped / cancelled);
* **backpressure** composes with the engine's admission control: when
  ``ContinuousBatcher.submit`` raises :class:`AdmissionError` (engine
  queue full), the request parks in a bounded **waiting room**; when the
  waiting room is full too, ``submit()`` re-raises ``AdmissionError`` to
  the caller — load shedding is explicit at every layer.  Waiting-room
  entries expire after ``queue_timeout`` seconds without engine
  admission (dropped, not served late);
* per-request **deadline SLOs**: a request with ``deadline_s`` set is
  dropped — cancelled inside the engine, slot and pages reclaimed — if
  its first token hasn't been produced ``deadline_s`` seconds after
  submit.  This is the serving analogue of DropCompute's compute
  threshold applied to *latency*: bounded-delay service with explicit,
  accounted drops instead of unbounded tail latency.

Engine state is only ever touched from the driver's serialization
points: submissions and cancellations land in host-side structures the
event loop owns, and the driver applies them to the engine *between*
steps.  Output streams are token-identical to driving the same engine
synchronously (``tests/test_serve_frontend.py`` pins this): per-slot KV
isolation means a request's greedy stream depends only on its own
prompt, never on how arrivals interleaved.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from collections import deque
from typing import AsyncIterator, Deque, Dict, List, Optional, Sequence

from .sampling import SamplingParams
from .scheduler import AdmissionError, ContinuousBatcher, Request, StepStats

#: stream terminator pushed into a RequestStream's token queue
_END = object()

#: lifecycle states a request moves through (events carry the same names)
QUEUED = "queued"
ADMITTED = "admitted"
FINISHED = "finished"
DROPPED = "dropped"
CANCELLED = "cancelled"


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One lifecycle transition of a request, host-timestamped."""

    kind: str  # queued | admitted | first_token | finished | dropped | cancelled
    time: float  # time.perf_counter()
    detail: str = ""  # e.g. the drop reason


class RequestStream:
    """Per-request handle: an async iterator over output tokens.

    Yields tokens in generation order as engine steps produce them; the
    iterator ends when the request finishes, is dropped (queue timeout /
    deadline), or is cancelled — check :attr:`status` to tell which.
    ``tokens`` holds everything yielded so far; ``events`` is the
    lifecycle log.
    """

    def __init__(self, fe: "AsyncEngine", req: Request,
                 deadline_s: Optional[float]):
        self._fe = fe
        self.request = req
        self.deadline_s = deadline_s
        self.tokens: List[int] = []
        self.events: List[StreamEvent] = []
        self.status = QUEUED
        self._published = 0
        self._queue: asyncio.Queue = asyncio.Queue()
        self._record(QUEUED, req.submitted_at)

    # -- identity / accounting ---------------------------------------------

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def ttft(self) -> Optional[float]:
        """Submit -> first token (seconds); None until the first token."""
        return self.request.ttft

    @property
    def queue_wait(self) -> Optional[float]:
        return self.request.queue_wait

    @property
    def truncated(self) -> bool:
        return self.request.truncated

    @property
    def met_deadline(self) -> bool:
        """First token arrived within ``deadline_s`` (vacuously true when
        no deadline was set — but False for a request that never produced
        a first token at all)."""
        if self.ttft is None:
            return False
        return self.deadline_s is None or self.ttft <= self.deadline_s

    # -- async iteration ----------------------------------------------------

    def __aiter__(self) -> AsyncIterator[int]:
        return self

    async def __anext__(self) -> int:
        item = await self._queue.get()
        if item is _END:
            raise StopAsyncIteration
        return item

    async def collect(self) -> List[int]:
        """Drain the stream to completion; returns the full output."""
        async for _ in self:
            pass
        return self.tokens

    def cancel(self) -> None:
        """Request cancellation.  Applied by the driver at its next
        serialization point (never mid-step); the stream then ends with
        ``status == "cancelled"``.  Idempotent; a no-op once final."""
        self._fe._request_cancel(self)

    # -- driver-side plumbing ----------------------------------------------

    def _record(self, kind: str, t: Optional[float] = None, detail: str = ""):
        self.events.append(
            StreamEvent(kind, time.perf_counter() if t is None else t, detail)
        )

    def _push(self, toks: Sequence[int]) -> None:
        for t in toks:
            self.tokens.append(int(t))
            self._queue.put_nowait(int(t))

    def _finalize(self, status: str, detail: str = "") -> None:
        self.status = status
        self._record(status, detail=detail)
        self._queue.put_nowait(_END)


class AsyncEngine:
    """Async front-end owning one :class:`ContinuousBatcher`'s step loop.

    Args:
      engine: the engine to drive.  Exclusively owned once ``start`` is
        called: nothing else may call ``step``/``submit``/``cancel`` on
        it until ``stop`` returns.
      waiting_room: bound on requests parked front-end-side when the
        engine's own admission queue is full.  ``submit()`` raises
        :class:`AdmissionError` beyond it — the caller-visible
        backpressure signal.
      queue_timeout: seconds a request may wait (waiting room + engine
        queue) without being admitted to a slot before it is dropped.
        None = wait forever.

    Use as an async context manager, or call ``start``/``stop``::

        async with AsyncEngine(engine) as fe:
            stream = await fe.submit(prompt, max_new_tokens=32)
            async for tok in stream:
                ...
    """

    def __init__(self, engine: ContinuousBatcher, *,
                 waiting_room: int = 256,
                 queue_timeout: Optional[float] = None):
        if waiting_room < 1:
            raise ValueError(f"waiting_room must be >= 1, got {waiting_room}")
        self._engine = engine
        self.waiting_room = waiting_room
        self.queue_timeout = queue_timeout
        self._waiting: Deque[RequestStream] = deque()
        self._live: Dict[int, RequestStream] = {}
        self._cancels: List[RequestStream] = []
        self._uids = itertools.count()
        self._driver: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._stopping = False
        self._abort = False
        self.step_log: List[StepStats] = []  # appended by the engine callback
        self.counters = {"submitted": 0, FINISHED: 0, DROPPED: 0, CANCELLED: 0}
        engine.add_step_callback(self.step_log.append)

    @property
    def engine(self) -> ContinuousBatcher:
        return self._engine

    @property
    def in_flight(self) -> int:
        """Requests accepted but not yet final (waiting room included)."""
        return len(self._waiting) + len(self._live)

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "AsyncEngine":
        if self._driver is not None:
            raise RuntimeError("AsyncEngine already started")
        self._wake = asyncio.Event()
        self._driver = asyncio.get_running_loop().create_task(self._drive())
        return self

    async def stop(self, *, drain: bool = True) -> None:
        """Stop the driver.  ``drain=True`` (default) first waits for
        every in-flight request to reach a final state; ``drain=False``
        cancels everything still in flight and returns."""
        if self._driver is None:
            return
        if drain:
            while self.in_flight:
                await asyncio.sleep(0.002)
        else:
            self._abort = True
        self._stopping = True
        self._wake.set()
        await self._driver
        self._driver = None

    async def __aenter__(self) -> "AsyncEngine":
        return await self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        await self.stop(drain=exc_type is None)

    # -- submission ---------------------------------------------------------

    async def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
                     uid: Optional[int] = None,
                     deadline_s: Optional[float] = None,
                     sampling: Optional[SamplingParams] = None
                     ) -> RequestStream:
        """Accept a request into the system and return its token stream.

        ``sampling`` carries the request's stochastic-decode knobs
        (``serve.sampling.SamplingParams``: temperature / top-k / top-p /
        seed); None = greedy argmax.  Identical (prompt, params, seed)
        replay identical streams — seeding is the caller's namespace, the
        front-end never invents entropy.

        Raises ``InvalidRequestError``/``AdmissionError`` immediately for
        requests the engine can never serve (``validate_request``), and
        ``AdmissionError`` when the waiting room is full — retry later or
        shed the load upstream.
        """
        if self._driver is None or self._stopping:
            raise RuntimeError("AsyncEngine is not running")
        if len(self._waiting) >= self.waiting_room:
            raise AdmissionError(
                f"waiting room full ({len(self._waiting)}/{self.waiting_room})"
            )
        if uid is None:
            uid = next(self._uids)
        if uid in self._live or any(h.uid == uid for h in self._waiting):
            raise ValueError(f"uid {uid} is already in flight")
        req = Request(uid=uid, prompt=list(prompt),
                      max_new_tokens=max_new_tokens,
                      sampling=sampling if sampling is not None
                      else SamplingParams())
        # TTFT measures from *here* — the user-visible submit — not from
        # engine admission; the engine honors a pre-stamped submitted_at
        req.submitted_at = time.perf_counter()
        self._engine.validate_request(req)
        stream = RequestStream(self, req, deadline_s)
        self._waiting.append(stream)
        self.counters["submitted"] += 1
        self._wake.set()
        return stream

    def _request_cancel(self, stream: RequestStream) -> None:
        if stream.status in (QUEUED, ADMITTED):
            self._cancels.append(stream)
            if self._wake is not None:
                self._wake.set()

    # -- driver -------------------------------------------------------------

    async def _drive(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while True:
                self._wake.clear()
                self._apply_cancels()
                self._feed()
                self._expire(time.perf_counter())
                if self._abort:
                    self._shed_all()
                if self._engine.busy:
                    # the blocking model step runs off-loop; arrivals and
                    # cancellations land in host structures meanwhile and
                    # are applied at the top of the next iteration
                    await loop.run_in_executor(None, self._engine.step)
                    self._publish()
                elif self._stopping:
                    break
                else:
                    # idle (or gated on queue_timeout): sleep until a
                    # submission/cancel/stop, re-checking expiries
                    # periodically
                    try:
                        await asyncio.wait_for(self._wake.wait(), timeout=0.05)
                    except asyncio.TimeoutError:
                        pass
        except Exception:
            # a driver crash must not strand clients on silent streams:
            # end every in-flight stream (the engine's state is suspect,
            # so don't touch it — no cancel/reclaim) and re-raise so
            # ``stop()`` surfaces the original error
            for stream in list(self._live.values()) + list(self._waiting):
                stream._finalize(DROPPED, detail="driver_error")
                self.counters[DROPPED] += 1
            self._live.clear()
            self._waiting.clear()
            raise

    def _feed(self) -> None:
        """Move waiting-room requests into the engine queue, oldest
        first, until the engine's admission control pushes back."""
        while self._waiting:
            stream = self._waiting[0]
            try:
                self._engine.submit(stream.request)
            except AdmissionError:
                break
            self._waiting.popleft()
            self._live[stream.uid] = stream

    def _expire(self, now: float) -> None:
        """Queue-timeout and TTFT-deadline drops.  Runs after ``_feed``
        so ``queue_timeout=0`` means "drop unless admittable right now"
        — an explicit load-shedding mode, not a race."""
        if self.queue_timeout is not None:
            while self._waiting:
                head = self._waiting[0]
                if now - head.request.submitted_at <= self.queue_timeout:
                    break  # FIFO: everything behind is younger
                self._waiting.popleft()
                head._finalize(DROPPED, detail="queue_timeout")
                self.counters[DROPPED] += 1
        for stream in list(self._live.values()) + list(self._waiting):
            r = stream.request
            if (stream.deadline_s is not None and r.first_token_at is None
                    and now - r.submitted_at > stream.deadline_s):
                self._drop(stream, detail="deadline")

    def _drop(self, stream: RequestStream, detail: str) -> None:
        if stream.uid in self._live:
            # reclaims the slot and every page the request held
            self._engine.cancel(stream.uid)
            del self._live[stream.uid]
        else:
            self._waiting.remove(stream)
        stream._finalize(DROPPED, detail=detail)
        self.counters[DROPPED] += 1

    def _apply_cancels(self) -> None:
        pending, self._cancels = self._cancels, []
        for stream in pending:
            if stream.status not in (QUEUED, ADMITTED):
                continue  # finished/dropped while the cancel was pending
            if stream.uid in self._live:
                self._engine.cancel(stream.uid)
                del self._live[stream.uid]
            else:
                self._waiting.remove(stream)
            stream._finalize(CANCELLED)
            self.counters[CANCELLED] += 1

    def _shed_all(self) -> None:
        for stream in list(self._live.values()) + list(self._waiting):
            self._drop(stream, detail="shutdown")

    def _publish(self) -> None:
        """After a step: stream newly produced tokens, emit lifecycle
        events, retire finished requests."""
        done = []
        for stream in self._live.values():
            r = stream.request
            if stream.status == QUEUED and r.admitted_at is not None:
                stream.status = ADMITTED
                stream._record(ADMITTED, r.admitted_at)
            if len(r.output) > stream._published:
                if stream._published == 0:
                    stream._record("first_token", r.first_token_at)
                stream._push(r.output[stream._published:])
                stream._published = len(r.output)
            if r.finished_at is not None and not r.cancelled:
                done.append(stream)
        for stream in done:
            del self._live[stream.uid]
            stream._finalize(
                FINISHED, detail="truncated" if stream.truncated else ""
            )
            self.counters[FINISHED] += 1

    # -- reporting ----------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        """Engine ``stats_summary`` plus front-end counters."""
        return {
            **self._engine.stats_summary(),
            **{f"frontend_{k}": float(v) for k, v in self.counters.items()},
            "frontend_waiting": float(len(self._waiting)),
            "frontend_live": float(len(self._live)),
        }
