from .scheduler import AdmissionError, ContinuousBatcher, Request, StepStats

__all__ = ["AdmissionError", "ContinuousBatcher", "Request", "StepStats"]
