from ..models.model import UnsupportedPatternError
from .block_table import OutOfPages, PagedTables, PageError
from .frontend import AsyncEngine, RequestStream, StreamEvent
from .kv import DenseSlots, KVCache, KVCacheSpec, KVState, Paged
from .packing import PackedLayout, pack_step, packed_capacity
from .sampling import (
    SamplingParams,
    residual_sample,
    sample_one,
    sample_tokens,
)
from .spec import (
    DraftModelProposer,
    NGramProposer,
    Proposer,
    SpecConfig,
    accept_greedy,
    accept_sampled,
)
from .scheduler import (
    AdmissionError,
    ContinuousBatcher,
    EngineStateError,
    InvalidRequestError,
    Request,
    StepStats,
    UnsupportedDistError,
)

__all__ = [
    "AdmissionError",
    "AsyncEngine",
    "ContinuousBatcher",
    "DenseSlots",
    "DraftModelProposer",
    "EngineStateError",
    "InvalidRequestError",
    "KVCache",
    "KVCacheSpec",
    "KVState",
    "NGramProposer",
    "OutOfPages",
    "PackedLayout",
    "Paged",
    "PagedTables",
    "PageError",
    "Proposer",
    "Request",
    "RequestStream",
    "SamplingParams",
    "SpecConfig",
    "StepStats",
    "StreamEvent",
    "UnsupportedDistError",
    "UnsupportedPatternError",
    "accept_greedy",
    "accept_sampled",
    "pack_step",
    "packed_capacity",
    "residual_sample",
    "sample_one",
    "sample_tokens",
]
