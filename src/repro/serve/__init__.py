from ..models.model import UnsupportedPatternError
from .packing import PackedLayout, pack_step, packed_capacity
from .scheduler import AdmissionError, ContinuousBatcher, Request, StepStats

__all__ = [
    "AdmissionError",
    "ContinuousBatcher",
    "PackedLayout",
    "Request",
    "StepStats",
    "UnsupportedPatternError",
    "pack_step",
    "packed_capacity",
]
