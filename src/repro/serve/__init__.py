from ..models.model import UnsupportedPatternError
from .block_table import OutOfPages, PagedTables, PageError
from .kv import DenseSlots, KVCache, KVCacheSpec, KVState, Paged
from .packing import PackedLayout, pack_step, packed_capacity
from .scheduler import (
    AdmissionError,
    ContinuousBatcher,
    Request,
    StepStats,
    UnsupportedDistError,
)

__all__ = [
    "AdmissionError",
    "ContinuousBatcher",
    "DenseSlots",
    "KVCache",
    "KVCacheSpec",
    "KVState",
    "OutOfPages",
    "PackedLayout",
    "Paged",
    "PagedTables",
    "PageError",
    "Request",
    "StepStats",
    "UnsupportedDistError",
    "UnsupportedPatternError",
    "pack_step",
    "packed_capacity",
]
