"""Continuous-batching serving engine with chunked prefill.

A fixed pool of B cache slots; requests are admitted into free slots as
they complete (vLLM-style iteration-level scheduling).  Every engine
iteration schedules a *mixed* batch of work:

* decode slots consume exactly one token (the previous output token);
* prefill slots consume up to ``chunk_size`` prompt tokens, written to
  the KV cache at the slot's absolute positions in a single
  ``prefill_chunk`` call — a 512-token prompt costs ~512/chunk_size
  engine steps to first token instead of 512.

Scheduling runs under a **per-step token budget** with a deadline-drop
policy, the serving analogue of DropCompute's Algorithm 1: the budget is
the compute threshold ``tau``, scheduled tokens are the micro-batches,
and prefill chunks past the threshold are *deferred to the next
iteration* rather than stalling every decode slot behind one long
prompt.  Two guarantees mirror the paper's semantics:

* decode slots are always scheduled (synchronous progress is preserved;
  only prefill becomes stochastic across iterations), and
* at least one prefill token is scheduled whenever prefill work is
  waiting (the analogue of ``min_microbatches=1`` — no starvation).

Shape stability: the dense mode compiles at most two programs per
session — a (B, chunk_size) mixed step and a (B, 1) decode-only step —
because the budget only changes the *contents* of the per-slot length
vector, never tensor shapes.  The packed mode compiles exactly one, at
the packed capacity (``packing.packed_capacity``).  Speculative decoding
(``spec=``) keeps the two-program story: decode steps widen to
(B, k + 1) verify grants and the mixed width becomes
``max(chunk_size, k + 1)`` — still fixed per engine configuration.

A consequence worth being precise about: per-step wall time is bounded
by the fixed cost of those two compiled programs, and the budget bounds
*scheduled tokens* (admission of new prefill work per iteration), which
is what spreads a long prompt across iterations so decode slots emit on
every one of them.  In the dense mode a mixed step computes the full
(B, chunk_size) shape regardless of how many tokens were granted;
``packed=True`` switches to the token-packed step program (vLLM-style
flattened batch, ``serve.packing`` + ``models.model.packed_prefill``)
whose compiled shape is the packed capacity — granted tokens alone
determine the compute, so the budget bounds actual per-step compute, not
just scheduled-token accounting.  Scheduling, deferral, and accounting
are shared between the two modes; the dense mode is the oracle the
packed parity suite (``tests/test_serve_packed.py``) compares against.

Decode is sampled per request (``Request.sampling`` — temperature /
top-k / top-p / seed; ``serve.sampling``): the step's logits feed a
jitted sampler instead of a bare argmax, with per-token PRNG keys
derived from (request seed, output index) so seeded streams replay
across restarts, step paths, and speculation.  The default params are
greedy and byte-identical to argmax decode.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ModelConfig
from ..models.model import (
    UnsupportedPatternError,
    init_decode_cache,
    packed_prefill,
    prefill_chunk,
    require_chunkable,
)
from . import packing
from .kv import KVCache, KVCacheSpec, reset_recurrent_state
from .sampling import SamplingParams, sample_tokens
from .spec import Proposer, SpecConfig, accept_sampled

PyTree = object


class UnsupportedDistError(NotImplementedError):
    """A serving mode was combined with a ``Distribution`` it cannot run
    under yet.  ``packed=True`` and ``cache="paged"`` both address KV by
    per-token indirection (slot gather / block tables) that would cross
    the sharded slot axis every step — making that gather mesh-aware is
    the ROADMAP "multi-host serving mesh" item.  Subclasses
    ``NotImplementedError`` so pre-existing handlers keep working."""


@functools.partial(jax.jit, static_argnames=("cfg", "moe_impl"))
def _engine_step(params, cfg: ModelConfig, cache, tokens, pos, lens,
                 moe_impl: str = "dense"):
    """Module-level jitted step: compilations are shared across engines
    with the same (cfg, shapes) — engine construction stays cheap.
    Returns ``(logits, cache, aux)``; ``aux["expert_overflow"]`` counts
    tokens the capacity-factor MoE router dropped this step (zero for
    dense dispatch and for MoE-free configs)."""
    return prefill_chunk(
        params, cfg, cache, tokens, pos, lens,
        moe_impl=moe_impl, return_aux=True,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "moe_impl"))
def _packed_engine_step(params, cfg: ModelConfig, cache, tokens, slot_ids, pos,
                        moe_impl: str = "dense"):
    """Token-packed step: one (capacity,) program per engine config."""
    return packed_prefill(
        params, cfg, cache, tokens, slot_ids, pos,
        moe_impl=moe_impl, return_aux=True,
    )


class AdmissionError(RuntimeError):
    """Raised by ``submit`` when the engine's wait queue is full."""


class InvalidRequestError(ValueError):
    """A request the engine can never serve correctly.

    Raised (never ``assert``-ed — asserts vanish under ``python -O``, and
    an admitted over-long request's out-of-range scatter writes are
    silently dropped, i.e. wrong tokens served) for: prompts longer than
    the slot can hold, empty prompts (decode would index
    ``prompt[-1]`` mid-step), and ``max_new_tokens < 1``.
    """


class EngineStateError(RuntimeError):
    """An engine lifecycle operation was called in the wrong state (e.g.
    ``reset_stats`` while requests are still in flight).  Raised, not
    ``assert``-ed, so the guard survives ``python -O``."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    #: per-request stochastic-decode knobs (``serve.sampling``); the
    #: default is greedy argmax — byte-identical to the pre-sampling
    #: engine.  Output token ``i`` is sampled with
    #: ``fold_in(PRNGKey(sampling.seed), i)`` regardless of step path
    #: (dense/packed/paged) or speculation, so seeded streams replay.
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    output: List[int] = dataclasses.field(default_factory=list)
    #: the engine finished this request short of ``max_new_tokens``
    #: (its slot ran out of cache positions) — surfaced instead of
    #: silently serving a truncated stream
    truncated: bool = False
    #: aborted via ``ContinuousBatcher.cancel`` before finishing
    cancelled: bool = False
    # --- latency accounting (filled in by the engine) ---
    #: ``submit`` stamps this only when unset, so a front-end that held
    #: the request in its own waiting room can pre-stamp the *original*
    #: arrival time and TTFT keeps measuring from the user-visible submit
    submitted_at: Optional[float] = None
    admitted_at: Optional[float] = None  # wall time the request got a slot
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    admitted_step: Optional[int] = None  # engine step the request got a slot
    first_token_step: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (seconds), submit -> first output token.
        Includes queue wait: the clock starts when the request entered
        the system, not when a slot freed up."""
        if self.submitted_at is None or self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def queue_wait(self) -> Optional[float]:
        """Seconds spent waiting for a cache slot (submit -> admission)."""
        if self.submitted_at is None or self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    @property
    def admitted_ttft(self) -> Optional[float]:
        """Seconds from slot admission to first output token — the
        prefill-side half of ``ttft`` (``ttft = queue_wait + this``)."""
        if self.admitted_at is None or self.first_token_at is None:
            return None
        return self.first_token_at - self.admitted_at

    @property
    def ttft_steps(self) -> Optional[int]:
        """Engine iterations from slot admission to first output token."""
        if self.admitted_step is None or self.first_token_step is None:
            return None
        return self.first_token_step - self.admitted_step + 1


@dataclasses.dataclass
class StepStats:
    """Per-iteration scheduling record (compute accounting for the budget)."""

    step: int
    decode_tokens: int  # decode slots fed (1 baseline token each)
    prefill_tokens: int  # prompt tokens consumed this step
    deferred_tokens: int  # prompt tokens pushed past the deadline
    wall_time: float  # host-measured step duration (seconds)
    shared_tokens: int = 0  # prompt tokens covered by prefix-cache pages
    used_pages: int = 0  # paged layout: pages referenced after this step
    draft_tokens: int = 0  # speculative draft tokens verified this step
    accepted_tokens: int = 0  # drafts the target model accepted
    queued_requests: int = 0  # requests waiting for a slot at step start
    #: scheduled tokens past ``token_budget`` this step.  The budget is a
    #: deferral threshold, not a hard cap: decode baselines are
    #: unconditional and the prefill starvation guard grants one token
    #: past an exhausted budget (see ``_schedule``), so a full decode
    #: batch under a tiny budget overshoots by design.  This field makes
    #: that overshoot explicit instead of letting BENCH records present
    #: tau as absolute.  Always 0 with no budget.
    budget_overshoot: int = 0
    #: routed (token, expert) assignments dropped to the residual path
    #: by the capacity-factor MoE dispatch this step — the per-expert
    #: mirror of ``budget_overshoot``: capacity is a static per-expert
    #: tau, and this is the work it deferred (here, *dropped*: MoE
    #: layers have a residual, so a dropped token still flows — it just
    #: skips the expert FFN).  Always 0 for dense dispatch and
    #: MoE-free configs.
    expert_overflow: int = 0

    @property
    def scheduled_tokens(self) -> int:
        return self.decode_tokens + self.draft_tokens + self.prefill_tokens


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # next absolute position to write

    @property
    def free(self) -> bool:
        return self.req is None

    @property
    def prefilling(self) -> bool:
        return self.req is not None and self.pos < len(self.req.prompt)


class ContinuousBatcher:
    """Engine: admit / step / drain.

    Args:
      params, cfg: model (attention-only patterns; see ``prefill_chunk``).
      batch_slots: cache slots B (max concurrent requests).
      max_len: per-slot cache length (prompt + generated tokens).
      chunk_size: max prompt tokens one slot consumes per step.
      token_budget: per-step compute cap in scheduled tokens — the serving
        ``tau``.  Decode slots always run; prefill fills the remainder and
        overflow chunks are deferred.  None = uncapped (schedule a full
        chunk for every prefilling slot).
      max_queue: admission control — ``submit`` raises ``AdmissionError``
        once this many requests are waiting for a slot.  None = unbounded.
      packed: run the token-packed step program instead of the dense
        (B, chunk_size) one.  The compiled shape is the packed capacity
        (``packing.packed_capacity``), so granted tokens alone determine
        per-step compute and the budget becomes a real compute bound.
        Scheduling and outputs are identical to the dense mode.
      cache: KV-cache layout — "dense" (one worst-case ``(max_len,)`` row
        per slot; the parity oracle), "paged" (page pool + block tables +
        prefix sharing; see ``repro.serve.kv``), or a ``KVCacheSpec``.
        Paged engines admit a request only when the page pool can cover
        its worst case (prompt + max_new, minus shareable prefix pages),
        map prefix-cache pages instead of re-prefilling shared prompt
        prefixes, and free pages on completion (retaining them for
        prefix reuse until the pool needs them back).
      page_size / num_pages: paged-layout knobs (tokens per page; pool
        size, default worst-case ``batch_slots * blocks_per_slot``).
      kv_dtype: paged pool element dtype (``KVCacheSpec.kv_dtype``).
        None = the compute dtype (bit-identical to dense); "int8" =
        quantized pages with per-row scales, ~half the bytes per page so
        the same HBM admits ~2x the pages (outputs are allclose to the
        oracle, not bit-identical).  Ignored when ``cache`` is already a
        ``KVCacheSpec``.
      spec: speculative decoding — a ``repro.serve.spec.SpecConfig`` (or a
        bare ``Proposer``, wrapped with the default ``k``).  Decode slots
        then verify up to ``k`` proposed tokens per step in one chunked
        verify grant (chunked prefill at the slot's absolute positions —
        the contract ``models.model.verify_step`` documents; the engine's
        one jitted step program serves prefill, decode, and verify
        grants alike), keep the draft prefix matching the target's
        per-column *sampled* tokens plus a bonus token
        (rejection-sampling acceptance — ``spec.accept_sampled``; the
        argmax prefix match when the request is greedy), and roll
        rejected KV back (position-mask trim for dense,
        ``KVCache.trim_slot`` for paged).
        Draft tokens are scheduled under ``token_budget`` with lower
        priority than decode baselines and higher than prefill chunks.
        Output streams are token-identical to the non-speculative
        engine — greedy or seeded-sampled alike — by construction.
      dist: optional ``repro.dist.Distribution`` — shards the decode cache
        (slots over the data axes, KV heads over "model") and the params
        by the path-based rules; the jitted engine step then partitions
        from the committed input shardings.  None = local placement.
      capacity_factor: MoE serving dispatch — when set (requires
        ``cfg.n_experts > 0``), expert FFNs run over fixed per-expert
        buffers of ``ceil(cf * tokens * top_k / n_experts)`` slots
        (``models.moe.apply_moe_capacity``) instead of the dense
        every-token-through-every-expert matmul.  Tokens past an
        expert's capacity are *dropped to the residual path* — the
        per-expert analogue of the token-budget ``tau``: a static
        compute bound enforced by deferrable-work dropping, reported
        per step as ``StepStats.expert_overflow`` (the per-expert
        mirror of ``budget_overshoot``).  ``float('inf')`` never drops
        and is byte-identical to dense dispatch; ``None`` (default)
        keeps the dense path.

    Recurrent patterns ('R'/'M' layers) serve through the same engine
    with two carve-outs, both rooted in the carried state being an
    in-place value rather than an append-only log: speculative decoding
    is refused at construction (rejected drafts cannot roll back state
    the scan already consumed), and paged prefix sharing is disabled
    (skipping shared prompt tokens would skip their recurrent-state
    updates — attention pages can be mapped, recurrent state cannot).
    """

    def __init__(
        self,
        params: PyTree,
        cfg: ModelConfig,
        batch_slots: int,
        max_len: int,
        chunk_size: int = 16,
        token_budget: Optional[int] = None,
        max_queue: Optional[int] = None,
        packed: bool = False,
        cache: "str | KVCacheSpec" = "dense",
        page_size: int = 16,
        num_pages: Optional[int] = None,
        kv_dtype: Optional[str] = None,
        spec: "Optional[SpecConfig | Proposer]" = None,
        dist=None,
        capacity_factor: Optional[float] = None,
    ):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        if isinstance(spec, Proposer):
            spec = SpecConfig(proposer=spec)
        self.spec = spec
        if spec is not None:
            spec.proposer.bind_engine(batch_slots, max_len)
        # fail at construction, not on the first step mid-trace
        require_chunkable(cfg, "ContinuousBatcher")
        self.recurrent = bool(set(cfg.pattern) & {"R", "M"})
        if self.recurrent and spec is not None:
            # raised here, not on the first rejected draft: trim_slot
            # would refuse mid-serve, stranding every in-flight request
            raise UnsupportedPatternError(
                "speculative decoding needs KV rollback of rejected "
                "drafts; recurrent state ('R'/'M' layers) has already "
                "consumed them and cannot roll back (see "
                "KVCache.trim_slot)"
            )
        if capacity_factor is not None:
            if cfg.n_experts <= 0:
                raise ValueError(
                    "capacity_factor is an MoE dispatch knob but the "
                    f"config has n_experts={cfg.n_experts}"
                )
            if capacity_factor <= 0:
                raise ValueError(
                    f"capacity_factor must be > 0, got {capacity_factor}"
                )
            # cfg is the jitted step's static arg: bake the factor in so
            # the compiled program's expert buffers are sized once
            cfg = dataclasses.replace(
                cfg, capacity_factor=float(capacity_factor)
            )
        self.moe_impl = "capacity" if capacity_factor is not None else "dense"
        if isinstance(cache, KVCacheSpec):
            kv_spec = cache
            # raised, not assert-ed: under python -O a mismatched spec
            # would serve silently-wrong tokens (too-few block tables /
            # scatter-dropped writes past the logical buffer)
            if kv_spec.num_slots != batch_slots or kv_spec.max_len != max_len:
                raise ValueError(
                    f"KVCacheSpec(num_slots={kv_spec.num_slots}, "
                    f"max_len={kv_spec.max_len}) disagrees with the engine's "
                    f"batch_slots={batch_slots}, max_len={max_len}"
                )
        else:
            kv_spec = KVCacheSpec(
                num_slots=batch_slots, max_len=max_len, layout=cache,
                page_size=page_size, num_pages=num_pages, kv_dtype=kv_dtype,
            )
        if packed and dist is not None:
            raise UnsupportedDistError(
                "packed=True with a Distribution is not supported yet: the "
                "per-token slot gather would cross the sharded slot axis "
                "every step (the ROADMAP multi-host serving-mesh item)"
            )
        if kv_spec.layout == "paged" and dist is not None:
            raise UnsupportedDistError(
                "cache='paged' with a Distribution is not supported yet: "
                "the block-table page gather would cross the sharded page "
                "pool every step (the ROADMAP multi-host serving-mesh item)"
            )
        self.packed = packed
        self.packed_capacity = (
            packing.packed_capacity(
                batch_slots, chunk_size, token_budget,
                draft_k=self.spec.k if self.spec is not None else 0,
            )
            if packed else None
        )
        # Second, smaller packed program for pure-decode steps (every
        # grant a single token, no drafts): capacity = batch_slots, so a
        # decode step's FFN/unembed run over B rows instead of the mixed
        # program's budget-sized capacity — the same two-program design
        # as the dense engine's (B, chunk) + (B, 1) pair.
        self.packed_decode_capacity = batch_slots if packed else None
        self.dist = dist
        if dist is not None:
            params = dist.shard(params)
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.chunk_size = chunk_size
        self.token_budget = token_budget
        self.max_queue = max_queue
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.kv: Optional[KVCache] = None
        if kv_spec.layout == "paged":
            self.kv = kv_spec.build(params, cfg)
            self.cache = self.kv.state
        else:
            build = functools.partial(
                init_decode_cache, params, cfg, batch_slots, max_len, linear=True
            )
            if dist is None:
                self.cache = build()
            else:
                # materialize directly into the sharded layout — building the
                # full cache on one device first would peak at the unsharded
                # size, the very thing sharding is for
                c_sh = dist.cache_shardings(jax.eval_shape(build))
                self.cache = jax.jit(build, out_shardings=c_sh)()
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.cancelled: Dict[int, Request] = {}
        self.steps = 0
        self.step_stats: List[StepStats] = []
        self._shared_step = 0
        self._overflow_step = 0
        self._step_callbacks: List = []

    # ------------------------------------------------------------------
    def add_step_callback(self, fn) -> None:
        """Register ``fn(stats: StepStats)`` to run at the end of every
        engine iteration, after the step's outputs and accounting have
        been committed.  The async front-end uses this to observe the
        step timeline; callbacks run on whatever thread drives ``step``
        and must not mutate engine state."""
        self._step_callbacks.append(fn)

    def validate_request(self, req: Request) -> None:
        """Reject a request the engine can never serve — without
        queueing it.  Raises ``InvalidRequestError`` for malformed
        requests and ``AdmissionError`` for ones the paged pool can
        never hold; the front-end calls this at its own submit time so
        a doomed request fails at the caller instead of timing out in
        the waiting room."""
        # raised, never assert-ed: under python -O an over-long request
        # would be admitted and its out-of-range scatter writes silently
        # dropped — wrong tokens served, no error anywhere
        if not req.prompt:
            raise InvalidRequestError(
                f"request {req.uid}: empty prompt (decode needs at least "
                f"one prompt token to condition on)"
            )
        if req.max_new_tokens < 1:
            raise InvalidRequestError(
                f"request {req.uid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}"
            )
        if not isinstance(req.sampling, SamplingParams):
            # a duck-typed stand-in would fail inside the jitted sampler
            # mid-step (or worse, coerce silently); reject at submit
            raise InvalidRequestError(
                f"request {req.uid}: sampling must be a SamplingParams, "
                f"got {type(req.sampling).__name__}"
            )
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise InvalidRequestError(
                f"request {req.uid} too long: {len(req.prompt)} prompt + "
                f"{req.max_new_tokens} new tokens > max_len {self.max_len}"
            )
        if self.kv is not None and self.kv.tables is not None:
            need = self.kv.tables.pages_required(
                len(req.prompt), req.max_new_tokens
            )
            if need > self.kv.num_pages:
                # admission is FIFO, so queueing an impossible request
                # would livelock it and everything behind it
                raise AdmissionError(
                    f"request references {need} pages at worst case but "
                    f"the pool has {self.kv.num_pages}; raise num_pages "
                    f"or split the request"
                )

    def submit(self, req: Request):
        self.validate_request(req)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            raise AdmissionError(
                f"queue full ({len(self.queue)}/{self.max_queue}); retry later"
            )
        if req.submitted_at is None:
            # pre-stamped by front-ends that queued the request upstream:
            # TTFT always measures from the user-visible submit
            req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def cancel(self, uid: int) -> bool:
        """Abort a request wherever it is — waiting in the queue, mid-
        prefill, or mid-decode.  Frees the slot (and, for the paged
        layout, decrefs every page the slot held: shared prefix pages
        survive with their other owners, fully-registered prompt pages
        move to the reclaimable prefix-cache tier, and the partially
        written tail page returns to the free list).  Returns True when
        the request was found live; a finished/unknown uid is False.

        Must not be called while ``step`` is executing (the async
        front-end serializes cancels between steps).
        """
        now = time.perf_counter()
        for k, r in enumerate(self.queue):
            if r.uid == uid:
                self.queue.pop(k)
                r.cancelled = True
                r.finished_at = now
                self.cancelled[uid] = r
                return True
        for i, s in enumerate(self.slots):
            if s.req is not None and s.req.uid == uid:
                r = s.req
                s.req = None  # dense rows are position-masked; no scrub
                r.cancelled = True
                r.finished_at = now
                self.cancelled[uid] = r
                if self.kv is not None:
                    self.kv.free_slot(i)
                if self.spec is not None:
                    self.spec.proposer.free_slot(i)
                return True
        return False

    def _dedup_inflight_prefix(self, head: Request) -> bool:
        """In-flight prefix dedup: should ``head`` stay queued because an
        active slot is still prefilling a prompt whose shareable prefix
        pages ``head`` will be able to map once they land?

        Prefix sharing only maps *fully-written* pages, so two identical
        prompts prefilling in lockstep would each write their own copy —
        duplicating the entire prefill.  Parking the duplicate until the
        leader's pages are published turns that into one prefill plus a
        page mapping.  Parking is bounded: the leader always progresses
        (the starvation guard grants it >= 1 token per step) and parking
        stops the moment the prefix cache can supply everything the
        leader will ever publish for this prompt — or the leader stops
        prefilling.
        """
        if self.recurrent:
            # prefix sharing is disabled for 'R'/'M' patterns (shared
            # tokens would skip recurrent-state updates), so no pages
            # will ever be published — parking would wait on nothing
            return False
        ps = self.kv.page_size
        limit = (len(head.prompt) - 1) // ps  # head's shareable-block cap
        if limit == 0:
            return False
        best = 0
        for s in self.slots:
            if s.free or not s.prefilling:
                continue
            p = s.req.prompt
            m = 0
            n_common = min(len(head.prompt), len(p))
            while m < n_common and head.prompt[m] == p[m]:
                m += 1
            best = max(best, min(m // ps, limit))
        if best == 0:
            return False
        return best * ps > self.kv.probe_shared(head.prompt)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s.free and self.queue:
                if self.kv is not None:
                    head = self.queue[0]
                    if self._dedup_inflight_prefix(head):
                        # park: the leader's prefix pages will cover this
                        # prompt; admission stays FIFO (no skip-ahead)
                        break
                    shared = self.kv.admit_slot(
                        i, head.prompt, head.max_new_tokens
                    )
                    if shared is None:
                        # the pool cannot guarantee the head request yet;
                        # admission stays FIFO (no skip-ahead starvation)
                        break
                else:
                    shared = 0
                    if self.recurrent:
                        # dense layout bypasses KVCache.admit_slot: zero
                        # the recycled slot's recurrent rows here.  KV
                        # rows are position-masked and need no scrub,
                        # but carried state is read unmasked every step
                        # — a previous tenant's h/conv/state would seed
                        # the new request.
                        self.cache = reset_recurrent_state(self.cache, [i])
                s.req = self.queue.pop(0)
                # prompt tokens covered by shared prefix pages are already
                # in the cache — skip straight past them
                s.pos = shared
                self._shared_step += shared
                s.req.admitted_step = self.steps
                s.req.admitted_at = time.perf_counter()

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    # ------------------------------------------------------------------
    def _propose(self) -> Dict[int, List[int]]:
        """Ask the speculative proposer for draft tokens per decode slot.

        The ask is clamped so the verify grant can never write past the
        slot's cache (``max_len``) or emit past the request's
        ``max_new_tokens`` — acceptance emits up to ``drafts + 1`` tokens.
        """
        if self.spec is None:
            return {}
        decode_slots = [
            i for i, s in enumerate(self.slots) if not s.free and not s.prefilling
        ]
        # drafts are granted from the budget left after the unconditional
        # decode baselines; don't pay proposer compute (a draft model is
        # real work) for tokens the scheduler can never grant
        headroom = (
            self.spec.k if self.token_budget is None
            else self.token_budget - len(decode_slots)
        )
        if headroom <= 0:
            return {}
        asks = []
        for i in decode_slots:
            s = self.slots[i]
            r = s.req
            k = min(
                self.spec.k,
                headroom,
                r.max_new_tokens - len(r.output) - 1,
                self.max_len - s.pos - 1,
            )
            if k > 0:
                asks.append((i, r.prompt + r.output, k))
        if not asks:
            return {}
        drafts = self.spec.proposer.propose_batch(asks)
        # never trust a proposer to honor the clamp it was given
        return {i: list(drafts.get(i, ()))[:k] for i, _, k in asks}

    def _schedule(self, drafts: Dict[int, List[int]]) -> List[int]:
        """Per-slot token counts for this step under the budget.

        Decode baselines first (1 token each, unconditional), then
        speculative draft tokens, then prefill chunks — both in admission
        order (oldest request first, NOT slot order — slots are recycled,
        so slot index says nothing about age) until ``token_budget`` is
        exhausted.  Draft tokens rank above prefill (they extend decode
        work, which the engine always prioritizes) but below baselines:
        with a tight budget spec degrades gracefully to plain decode.
        The oldest prefilling request is always granted >= 1 token, so
        under sustained load every prompt reaches the head of the line
        and makes progress: no starvation.

        The budget may therefore be exceeded, in exactly two intentional
        ways (both are liveness guarantees, mirroring the paper's
        semantics — only *deferrable* work is stochastic across steps):

        1. decode baselines are unconditional — up to ``batch_slots``
           tokens are scheduled even when ``token_budget`` is smaller,
           so every in-flight request emits on every step;
        2. the starvation guard grants the oldest prefilling slot one
           token past an exhausted budget (the ``min_microbatches=1``
           analogue), so a prompt behind a full decode batch still
           reaches its first token.

        ``packing.packed_capacity`` sizes the packed program for both
        exceptions, and each step reports the realized excess as
        ``StepStats.budget_overshoot``.
        """
        n = [0] * len(self.slots)
        spent = 0
        prefill, decode = [], []
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            if not s.prefilling:
                n[i] = 1  # decode baseline: always scheduled
                spent += 1
                decode.append(i)
            else:
                prefill.append(i)
        by_age = lambda i: (self.slots[i].req.admitted_step, self.slots[i].req.uid)
        decode.sort(key=by_age)
        for i in decode:
            want = len(drafts.get(i, ()))
            left = want if self.token_budget is None else self.token_budget - spent
            grant = min(want, max(left, 0))
            n[i] += grant
            spent += grant
        prefill.sort(key=by_age)
        for rank, i in enumerate(prefill):
            s = self.slots[i]
            want = min(self.chunk_size, len(s.req.prompt) - s.pos)
            left = want if self.token_budget is None else self.token_budget - spent
            grant = min(want, max(left, 0))
            if grant == 0 and rank == 0:
                grant = 1  # starvation guard (min_microbatches analogue)
            n[i] = grant
            spent += grant
        return n

    def _run_dense(self, grants, out_base) -> Dict[int, np.ndarray]:
        """Dense (B, C) step.  Returns {slot: per-granted-column sampled
        tokens} — the last column is the emitted/bonus token, the earlier
        columns are what the speculative verifier checks drafts against.
        Greedy slots (``temperature == 0``, the default) sample by
        raw-logits argmax: byte-identical to the pre-sampling engine.

        ``out_base`` maps slot -> output index of the grant's first
        column's prediction (negative mid-prefill; those columns' samples
        are discarded, so their key indices are clamped at 0).
        """
        b = len(self.slots)
        mixed = any(self.slots[i].prefilling for i, _, _ in grants)
        c = self.chunk_size if mixed else 1
        if self.spec is not None:
            # verify grants are up to 1 + k wide; keep the two-programs
            # shape story by folding them into fixed widths
            c = max(c, self.spec.k + 1) if mixed else self.spec.k + 1
        tokens = np.zeros((b, c), np.int32)
        pos = np.zeros((b,), np.int32)
        lens = np.zeros((b,), np.int32)
        seeds = np.zeros((b, c), np.uint32)
        oidx = np.zeros((b, c), np.int32)
        temps = np.zeros((b, c), np.float32)  # unused rows: argmax, discarded
        topk = np.zeros((b, c), np.int32)
        topp = np.ones((b, c), np.float32)
        for i, pos0, toks in grants:
            n = len(toks)
            tokens[i, :n] = toks
            pos[i] = pos0
            lens[i] = n
            sp = self.slots[i].req.sampling
            seeds[i] = sp.seed & 0xFFFFFFFF
            temps[i] = sp.temperature
            topk[i] = sp.top_k
            topp[i] = sp.top_p
            oidx[i, :n] = np.maximum(out_base[i] + np.arange(n), 0)
        logits, self.cache, aux = _engine_step(
            self.params, self.cfg, self.cache, jnp.asarray(tokens),
            jnp.asarray(pos), jnp.asarray(lens), moe_impl=self.moe_impl,
        )
        # Synchronize every step (np.asarray blocks on the result; the
        # jitted sampler dispatches asynchronously in the same chain, so
        # sampling adds no extra sync).  The sync itself is load-bearing:
        # with async dispatch, rebinding the host token/pos buffers while
        # the step is still in flight corrupts the computation on
        # jax<=0.4 CPU (observed use-after-free garbage).
        next_tok = np.asarray(sample_tokens(
            logits, seeds, oidx, temps, topk, topp
        ))  # (B, C)
        self._overflow_step = int(np.asarray(aux["expert_overflow"]))
        return {i: next_tok[i, : len(toks)] for i, _, toks in grants}

    def _run_packed(self, grants, out_base) -> Dict[int, np.ndarray]:
        """Token-packed (capacity,) step: compute scales with grants.

        Pure-decode steps (every grant one token) take the decode-sized
        program; any prefill or draft widens a grant past one token and
        routes to the mixed-capacity program.  Sampling params and
        per-position key indices are slot-gathered per packed entry
        (``PackedLayout.out_idx``), so a packed row samples exactly what
        the dense row for the same (request, output index) samples.
        """
        capacity = self.packed_capacity
        if all(len(toks) == 1 for _, _, toks in grants):
            capacity = self.packed_decode_capacity
        layout = packing.pack_step(grants, capacity, out_base=out_base)
        seeds = np.zeros((capacity,), np.uint32)
        temps = np.zeros((capacity,), np.float32)  # padding: argmax, discarded
        topk = np.zeros((capacity,), np.int32)
        topp = np.ones((capacity,), np.float32)
        for i, (j, m) in layout.spans.items():
            sp = self.slots[i].req.sampling
            seeds[j : j + m] = sp.seed & 0xFFFFFFFF
            temps[j : j + m] = sp.temperature
            topk[j : j + m] = sp.top_k
            topp[j : j + m] = sp.top_p
        logits, self.cache, aux = _packed_engine_step(
            self.params, self.cfg, self.cache, jnp.asarray(layout.tokens),
            jnp.asarray(layout.slot_ids), jnp.asarray(layout.positions),
            moe_impl=self.moe_impl,
        )
        next_tok = np.asarray(sample_tokens(
            logits, seeds, layout.out_idx, temps, topk, topp
        ))  # (P,) — syncs
        self._overflow_step = int(np.asarray(aux["expert_overflow"]))
        return {i: next_tok[j : j + m] for i, (j, m) in layout.spans.items()}

    def step(self):
        """One engine iteration: mixed chunked-prefill + decode/verify."""
        t0 = time.perf_counter()
        queued0 = len(self.queue)  # queue depth before this step's admission
        self._shared_step = 0
        self._overflow_step = 0  # set by the step runner from the jit aux
        self._admit()
        if self.kv is not None:
            # lazy prefix sharing: an older request may have finished
            # writing pages this prompt can map since the last step
            for i, s in enumerate(self.slots):
                if not s.free and s.prefilling:
                    n_sh = self.kv.share(i, s.req.prompt, s.pos)
                    if n_sh:
                        s.pos += n_sh
                        self._shared_step += n_sh
        drafts = self._propose()
        n = self._schedule(drafts)
        decode_toks = prefill_toks = deferred = draft_toks = accepted_toks = 0
        grants: List[packing.Grant] = []  # (slot, start pos, tokens)
        granted_draft: Dict[int, List[int]] = {}
        # slot -> output index of the grant's first column's prediction:
        # column c at absolute position pos + c predicts position
        # pos + c + 1, i.e. output index pos + c + 1 - len(prompt)
        # (negative mid-prefill — those columns' samples are discarded).
        # This feeds the sampler's per-position PRNG keys, which must
        # depend only on (request seed, output index) for seeded streams
        # to replay across step paths and speculation.
        out_base: Dict[int, int] = {}
        for i, s in enumerate(self.slots):
            if s.free or n[i] == 0:
                if not s.free and s.prefilling:
                    deferred += min(self.chunk_size, len(s.req.prompt) - s.pos)
                continue
            r = s.req
            if s.prefilling:
                toks = r.prompt[s.pos : s.pos + n[i]]
                prefill_toks += n[i]
                deferred += max(
                    min(self.chunk_size, len(r.prompt) - s.pos) - n[i], 0
                )
            else:
                # the budget may have truncated the proposer's draft
                draft = drafts.get(i, [])[: n[i] - 1]
                granted_draft[i] = draft
                toks = [r.output[-1] if r.output else r.prompt[-1]] + draft
                decode_toks += 1
                draft_toks += len(draft)
            out_base[i] = s.pos + 1 - len(r.prompt)
            grants.append((i, s.pos, toks))

        if self.kv is not None:
            # allocate (and copy-on-write, if any page is shared) every
            # page this step's grants will scatter into, then hand the
            # refreshed block tables to the jitted step
            self.kv.prepare_step(grants)
            self.cache = self.kv.state
        used_pages = self.kv.used_pages if self.kv is not None else 0

        sampled = (
            self._run_packed(grants, out_base)
            if self.packed
            else self._run_dense(grants, out_base)
        )
        if self.kv is not None:
            self.kv.state = self.cache

        now = time.perf_counter()
        for i, s in enumerate(self.slots):
            if s.free or n[i] == 0:
                continue
            r = s.req
            was_prefilling = s.prefilling
            if was_prefilling:
                s.pos += n[i]
                if self.kv is not None:
                    # publish fully-written prompt pages for prefix sharing
                    self.kv.register_prompt_pages(i, r.prompt, s.pos)
                if s.pos < len(r.prompt):
                    continue  # still mid-prompt; no token emitted this step
                emitted = [int(sampled[i][n[i] - 1])]
            else:
                # verify: rejection-sampling acceptance — keep the draft
                # prefix matching the target's per-column samples (+ the
                # bonus/resampled token), roll back the rejected tail's
                # KV.  Greedy params make this the argmax prefix match.
                accepted, emitted = accept_sampled(granted_draft[i], sampled[i])
                remaining = r.max_new_tokens - len(r.output)
                if len(emitted) > remaining:
                    # Clamp: a request asking for N tokens must never
                    # stream N+k (the proposer ask is clamped too, but
                    # this is the structural guarantee — spec streams are
                    # length-identical to greedy even against a proposer
                    # that ignores its ask).  The clamped tail's KV is
                    # left untrimmed: the request finishes this step and
                    # free_slot reclaims every page.
                    emitted = emitted[:remaining]
                    accepted = len(emitted) - 1
                    s.pos += 1 + accepted
                else:
                    s.pos += 1 + accepted
                    if self.kv is not None and accepted < len(granted_draft[i]):
                        self.kv.trim_slot(i, s.pos)
                accepted_toks += accepted
            r.output.extend(emitted)
            if r.first_token_at is None:
                r.first_token_at = now
                r.first_token_step = self.steps
            if r.done or s.pos >= self.max_len:
                # a slot out of cache positions ends the request early;
                # flag it rather than silently serving a short stream
                r.truncated = not r.done
                r.finished_at = now
                self.finished[r.uid] = r
                s.req = None  # slot becomes available next step
                if self.kv is not None:
                    self.kv.free_slot(i)
                if self.spec is not None:
                    self.spec.proposer.free_slot(i)

        scheduled = decode_toks + draft_toks + prefill_toks
        stats = StepStats(
            self.steps, decode_toks, prefill_toks, deferred, now - t0,
            shared_tokens=self._shared_step,
            used_pages=used_pages,
            draft_tokens=draft_toks,
            accepted_tokens=accepted_toks,
            queued_requests=queued0,
            budget_overshoot=(
                max(scheduled - self.token_budget, 0)
                if self.token_budget is not None else 0
            ),
            expert_overflow=self._overflow_step,
        )
        self.step_stats.append(stats)
        self.steps += 1
        for fn in self._step_callbacks:
            fn(stats)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while self.busy and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # ------------------------------------------------------------------
    def reset_stats(self):
        """Clear per-step and per-request accounting (e.g. after warmup).

        The KV cache contents are left as-is: slots are position-masked,
        so stale rows from earlier requests are never attended.  Paged
        page-usage counters rebaseline (``KVCache.reset_accounting``) so
        ``touched_pages`` counts only post-reset page traffic — live and
        prefix-cached pages survive.
        """
        if self.busy:
            # raised, not assert-ed: under python -O a mid-flight reset
            # would silently corrupt every in-flight request's accounting
            raise EngineStateError("reset_stats while requests are in flight")
        self.steps = 0
        self.step_stats = []
        self.finished = {}
        self.cancelled = {}
        self._shared_step = 0  # stale counter from the last step otherwise
        self._overflow_step = 0
        if self.kv is not None:
            self.kv.reset_accounting()

    def stats_summary(self) -> Dict[str, float]:
        """Aggregate engine + latency statistics.

        TTFT is split into its two phases so queue pressure is visible:
        ``queue_wait`` (submit -> slot admission — invisible compute-side,
        dominated by slot contention) and ``admitted_ttft`` (admission ->
        first token — the prefill-side latency the chunk/budget knobs
        control).  ``ttft = queue_wait + admitted_ttft`` per request; all
        three report mean/p50/p99.
        """
        st = self.step_stats
        done = list(self.finished.values())
        ttfts = [r.ttft for r in done if r.ttft is not None]

        def pct(values, q):
            return float(np.quantile(values, q)) if values else float("nan")

        def dist(prefix, values):
            return {
                f"mean_{prefix}": float(np.mean(values)) if values else float("nan"),
                f"p50_{prefix}": pct(values, 0.50),
                f"p99_{prefix}": pct(values, 0.99),
            }
        paged = (
            {
                "shared_tokens": float(sum(s.shared_tokens for s in st)),
                "peak_used_pages": float(max((s.used_pages for s in st), default=0)),
                "touched_pages": float(self.kv.tables.touched_pages),
                "num_pages": float(self.kv.num_pages),
            }
            if self.kv is not None
            else {}
        )
        n_draft = sum(s.draft_tokens for s in st)
        n_accept = sum(s.accepted_tokens for s in st)
        spec = (
            {
                "draft_tokens": float(n_draft),
                "accepted_tokens": float(n_accept),
                "acceptance_rate": (
                    n_accept / n_draft if n_draft else float("nan")
                ),
            }
            if self.spec is not None
            else {}
        )
        generated = sum(len(r.output) for r in done)
        waits = [r.queue_wait for r in done if r.queue_wait is not None]
        admitted = [r.admitted_ttft for r in done if r.admitted_ttft is not None]
        return {
            **paged,
            **spec,
            "generated_tokens": float(generated),
            "steps_per_token": (
                self.steps / generated if generated else float("nan")
            ),
            "truncated": float(sum(r.truncated for r in done)),
            "cancelled": float(len(self.cancelled)),
            "steps": float(self.steps),
            "max_step_tokens": float(max((s.scheduled_tokens for s in st), default=0)),
            "mean_step_tokens": float(
                np.mean([s.scheduled_tokens for s in st]) if st else 0.0
            ),
            # tau is a deferral threshold, not a hard cap (decode
            # baselines + the starvation guard; see _schedule) — report
            # the realized excess so BENCH consumers see it
            "budget_overshoot_tokens": float(
                sum(s.budget_overshoot for s in st)
            ),
            "max_budget_overshoot": float(
                max((s.budget_overshoot for s in st), default=0)
            ),
            # capacity-factor MoE dispatch: (token, expert) routes the
            # per-expert capacity dropped to the residual path — the
            # per-expert analogue of the deferral accounting above
            "expert_overflow_tokens": float(
                sum(s.expert_overflow for s in st)
            ),
            "max_expert_overflow": float(
                max((s.expert_overflow for s in st), default=0)
            ),
            "mean_queued_requests": float(
                np.mean([s.queued_requests for s in st]) if st else 0.0
            ),
            "deferred_tokens": float(sum(s.deferred_tokens for s in st)),
            "max_step_wall": float(max((s.wall_time for s in st), default=0.0)),
            "finished": float(len(done)),
            "mean_ttft": float(np.mean(ttfts)) if ttfts else float("nan"),
            "p50_ttft": pct(ttfts, 0.50),
            "p99_ttft": pct(ttfts, 0.99),
            **dist("queue_wait", waits),
            **dist("admitted_ttft", admitted),
        }
