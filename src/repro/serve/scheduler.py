"""Continuous-batching serving scheduler.

A fixed pool of B cache slots; requests are admitted into free slots as
they complete (vLLM-style iteration-level scheduling).  Every engine step
decodes ONE token for all active slots via the per-slot-position
``decode_step`` path (each sequence at its own absolute position in its
own cache rows).  Prefill is streamed through the same decode path
token-by-token — simple, cache-correct, and shape-stable (one compiled
program for the whole serving session).

This is the serving-side analogue of DropCompute's scheduling philosophy:
keep the synchronous engine step, let per-slot state absorb the
heterogeneity (here: request lengths; there: compute variance).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ModelConfig
from ..models.model import decode_step, init_decode_cache

PyTree = object


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    output: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # next absolute position to write

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatcher:
    """Engine: admit / step / drain."""

    def __init__(self, params: PyTree, cfg: ModelConfig, batch_slots: int, max_len: int):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.cache = init_decode_cache(params, cfg, batch_slots, max_len)
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos, moe_impl="dense")
        )
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        assert len(req.prompt) + req.max_new_tokens <= self.max_len, "request too long"
        self.queue.append(req)

    def _admit(self):
        for s in self.slots:
            if s.free and self.queue:
                s.req = self.queue.pop(0)
                s.pos = 0

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: feed each active slot its next token."""
        self._admit()
        b = len(self.slots)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            r = s.req
            if s.pos < len(r.prompt):  # streaming prefill
                tokens[i, 0] = r.prompt[s.pos]
            else:  # decode: feed the last generated token
                tokens[i, 0] = r.output[-1] if r.output else r.prompt[-1]
            pos[i] = s.pos

        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos)
        )
        next_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))

        for i, s in enumerate(self.slots):
            if s.free:
                continue
            r = s.req
            s.pos += 1
            if s.pos >= len(r.prompt):  # this step produced a new token
                r.output.append(int(next_tok[i]))
            if r.done or s.pos >= self.max_len:
                self.finished[r.uid] = r
                s.req = None  # slot becomes available next step

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while self.busy and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
