"""``repro.serve.kv`` — the first-class KV-cache API.

The serving engine used to own a raw ``(B, L)`` slot cache and every
consumer poked at its arrays directly.  This module makes the cache a
contract instead:

    spec = KVCacheSpec(num_slots=8, max_len=512, layout="paged")
    kv = spec.build(params, cfg)            # -> KVCache (host handle)
    logits, kv.state = prefill_chunk(params, cfg, kv.state, ...)

``KVCache.state`` is a :class:`KVState` — a registered pytree the model
paths (``prefill_chunk`` / ``packed_prefill`` / ``decode_step``) accept
anywhere they accept the legacy cache dict.  Two interchangeable layouts:

* :class:`DenseSlots` — today's ``(B, L)`` rows, one per slot, worst-case
  provisioned.  Kept as the parity oracle: paged must be token-identical.
* :class:`Paged` — a flat ``(num_pages, page_size)`` pool per layer plus
  per-slot block tables (``repro.serve.block_table``).  A ``(slot, pos)``
  cache address becomes ``(table[slot, pos // page_size], pos % page_size)``;
  ref-counted pages let requests share a common prompt prefix's KV
  (near-zero prefill for shared-prefix workloads) and copy-on-write keeps
  forks safe.  Memory is provisioned for *actual* tokens, not worst case,
  so the same bytes admit ~``max_len / mean_request_len`` x more
  concurrent requests.

The translation math itself (``paged_index`` / ``paged_gather``) lives in
``repro.models.layers`` — the one place both this module and the model
stack can import it without a cycle — and the layouts expose it as their
``index``/``gather`` so kernels and tests program against the layout, not
the arrays.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..models import layers as L
from ..models.model import (
    UnsupportedPatternError,
    init_decode_cache,
    require_chunkable,
)
from ..models.transformer import _unit_and_groups, init_block_cache
from .block_table import PagedTables

PyTree = Any


# ---------------------------------------------------------------------------
# KVState — the device-side pytree every model cache path accepts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVState:
    """Device KV state: the per-layer cache pytree plus, for the paged
    layout, the block-table array.  ``page_size == 0`` means dense slots
    (``tables`` is ``None`` and ``data`` is exactly the legacy cache
    dict).  Registered as a pytree, so it passes through ``jax.jit``.

    ``data`` is a *heterogeneous* per-layer-kind pytree — the LayerState
    protocol.  Each layer carries the state its kind needs:

    * ``'G'``/``'L'`` (attention) — ``{"attn": ...}`` KV rows, dense
      ``(num_slots, L)`` or a paged ``(num_pages, page_size)`` pool
      addressed through ``tables``;
    * ``'R'`` (RG-LRU) — ``{"rglru": {"h", "conv"}}``, fixed-size
      per-slot recurrent state with leading dim ``num_slots`` in *both*
      layouts (recurrent state is O(1) per slot — nothing to page);
    * ``'M'`` (SSD/Mamba-2) — ``{"ssd": {"state", "conv"}}``, same rule.

    The leaf kind decides every lifecycle op: page ops (COW copies, block
    tables) apply only to attention leaves; admission zeroes a slot's
    recurrent rows (``reset_recurrent_state``); **fork of recurrent state
    is an eager row copy, not a page share** — there is no meaningful COW
    for a value the very next step overwrites in place — and trim/rollback
    is impossible (the state has already consumed the trimmed tokens), so
    speculative decoding is refused for 'R'/'M' patterns."""

    data: PyTree
    tables: Optional[jnp.ndarray] = None  # (num_slots, num_blocks) int32
    page_size: int = 0  # static (pytree aux): 0 = dense

    @property
    def is_paged(self) -> bool:
        return self.page_size > 0


def _kvstate_flatten_with_keys(s: KVState):
    children = (
        (jax.tree_util.GetAttrKey("data"), s.data),
        (jax.tree_util.GetAttrKey("tables"), s.tables),
    )
    return children, s.page_size


def _kvstate_flatten(s: KVState):
    return (s.data, s.tables), s.page_size


def _kvstate_unflatten(aux, children) -> KVState:
    return KVState(data=children[0], tables=children[1], page_size=aux)


jax.tree_util.register_pytree_with_keys(
    KVState, _kvstate_flatten_with_keys, _kvstate_unflatten, _kvstate_flatten
)


def _path_has(path, keys) -> bool:
    return any(
        isinstance(e, jax.tree_util.DictKey) and e.key in keys for e in path
    )


def _is_recurrent_path(path) -> bool:
    """Recurrent-state leaves ('R'/'M' layers) are slot-indexed, never
    page-indexed — every page op must skip them."""
    return _path_has(path, ("rglru", "ssd"))


def copy_pages_state(state: KVState, ops: Sequence[Tuple[int, int]]) -> KVState:
    """Apply ``(src, dst)`` page copies to every pool leaf (the device half
    of copy-on-write).  Group-scanned leaves carry a leading ``n_groups``
    dim ahead of the page axis — decided by tree path (``"groups"``), not
    rank, because int8 pools add per-row scale leaves whose rank collides
    with the un-grouped k/v pools.  Recurrent leaves are slot-indexed, not
    page-indexed, and pass through untouched."""
    if not ops:
        return state
    src = jnp.asarray([s for s, _ in ops], jnp.int32)
    dst = jnp.asarray([d for _, d in ops], jnp.int32)

    def leaf(path, x):
        if _is_recurrent_path(path):
            return x
        if _path_has(path, ("groups",)):  # (n_groups, num_pages, ...)
            return x.at[:, dst].set(x[:, src])
        return x.at[dst].set(x[src])  # (num_pages, ...)

    return dataclasses.replace(
        state, data=jax.tree_util.tree_map_with_path(leaf, state.data)
    )


def reset_recurrent_state(data: PyTree, slots) -> PyTree:
    """Zero the recurrent-state rows of ``slots`` in a cache pytree — the
    admission-time counterpart of mapping fresh KV pages (a freed slot's
    stale h/conv/state must not leak into its next tenant).  Attention
    leaves pass through untouched; no-op pytree-wise for pure-attention
    patterns.  Accepts the raw ``data`` tree (dict or ``KVState.data``)."""
    slots = jnp.asarray(slots, jnp.int32)

    def leaf(path, x):
        if not _is_recurrent_path(path):
            return x
        if _path_has(path, ("groups",)):  # (n_groups, num_slots, ...)
            return x.at[:, slots].set(0)
        return x.at[slots].set(0)

    return jax.tree_util.tree_map_with_path(leaf, data)


def copy_recurrent_state(data: PyTree, src: int, dst: int) -> PyTree:
    """Copy slot ``src``'s recurrent rows onto ``dst`` — the fork path.
    Unlike attention KV, forked recurrent state is an eager copy (COW
    would buy nothing: the next step rewrites the row in place)."""

    def leaf(path, x):
        if not _is_recurrent_path(path):
            return x
        if _path_has(path, ("groups",)):
            return x.at[:, dst].set(x[:, src])
        return x.at[dst].set(x[src])

    return jax.tree_util.tree_map_with_path(leaf, data)


# ---------------------------------------------------------------------------
# Layouts
# ---------------------------------------------------------------------------


class DenseSlots:
    """One ``(max_len,)`` row of KV per slot — the worst-case layout and
    the parity oracle for :class:`Paged`."""

    name = "dense"

    @staticmethod
    def build_data(spec: "KVCacheSpec", params: PyTree, cfg) -> PyTree:
        return init_decode_cache(
            params, cfg, spec.num_slots, spec.max_len, linear=True
        )

    @staticmethod
    def index(slot, position):
        """(slot, position) -> physical (row, column): the identity."""
        return slot, position


class Paged:
    """Flat page pool + block tables; ``index``/``gather`` are the jit-side
    translation used by ``models.layers`` and the paged flash kernel."""

    name = "paged"

    # the (slot, pos) -> (page, offset) translation and the pool -> logical
    # buffer gather, shared with the attention paths (defined models-side
    # to keep the import DAG acyclic)
    index = staticmethod(L.paged_index)
    gather = staticmethod(L.paged_gather)

    @staticmethod
    def build_data(spec: "KVCacheSpec", params: PyTree, cfg) -> PyTree:
        require_chunkable(cfg, "the paged KV layout")
        num_pages, ps = spec.resolve_pages(cfg), spec.page_size
        kv, hd = cfg.n_kv_heads, cfg.hd
        dtype = spec.resolved_kv_dtype(cfg)

        def one_layer(kind):
            if kind in ("R", "M"):
                # recurrent state is O(1) per slot: the same fixed-size
                # slot-indexed rows as the dense layout, living beside
                # the page pools (never addressed through block tables)
                return init_block_cache(cfg, kind, spec.num_slots, 1)
            z = jnp.zeros((num_pages, ps, kv, hd), dtype)
            layer = {"attn": {"k": z, "v": z + 0}}
            if spec.kv_dtype == "int8":
                # per-row dequant scales (1.0 = the all-zero pool rows'
                # identity scale, matching the write path's convention)
                s = jnp.ones((num_pages, ps, kv), jnp.float32)
                layer["attn"]["k_scale"] = s
                layer["attn"]["v_scale"] = s + 0
            return layer

        unit, n_groups, tail = _unit_and_groups(cfg)
        groups = tuple(
            jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(),
                one_layer(kind),
            )
            for kind in unit
        )
        tail_cs = [
            one_layer(cfg.pattern[n_groups * len(unit) + i]) for i in range(tail)
        ]
        return {"stack": {"groups": groups, "tail": tail_cs}}


_LAYOUTS = {DenseSlots.name: DenseSlots, Paged.name: Paged}


# ---------------------------------------------------------------------------
# Spec + host handle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Declarative description of a serving KV cache.

    num_slots: concurrent requests the cache addresses (block tables).
    max_len: maximum absolute position any slot may write.
    layout: "dense" (worst-case rows, the oracle) or "paged".
    page_size: tokens per page (paged only).
    num_pages: pool size; ``None`` = worst-case provisioning
        (``num_slots * blocks_per_slot`` — parity-safe; size it smaller to
        oversubscribe on the actual-token distribution, which is the point).
    kv_dtype: pool element type (paged only).  ``None`` = the model's
        compute dtype (bit-identical to dense).  ``"int8"`` = quantized
        pages with per-row f32 scales — roughly half the bytes per page,
        so a fixed HBM budget holds ~2x the pages, and page count is the
        concurrency ceiling.  Any other float dtype string (e.g.
        ``"bfloat16"``) stores pages in that dtype unscaled.
    """

    num_slots: int
    max_len: int
    layout: str = "dense"
    page_size: int = 16
    num_pages: Optional[int] = None
    kv_dtype: Optional[str] = None

    def __post_init__(self):
        if self.layout not in _LAYOUTS:
            raise ValueError(f"unknown KV layout {self.layout!r}; want dense|paged")
        if self.num_slots < 1 or self.max_len < 1 or self.page_size < 1:
            raise ValueError(  # typed, not assert: must survive python -O
                f"KVCacheSpec sizes must be >= 1: num_slots={self.num_slots}, "
                f"max_len={self.max_len}, page_size={self.page_size}"
            )
        if self.kv_dtype is not None:
            if self.layout != "paged":
                raise ValueError("kv_dtype is a paged-layout knob; dense slots "
                                 "always use the compute dtype")
            jnp.zeros((), self.kv_dtype)  # raises on unknown dtype strings

    @property
    def layout_cls(self):
        return _LAYOUTS[self.layout]

    def buffer_len(self, cfg) -> int:
        """Logical per-slot buffer length: like ``linear=True`` dense
        caches, sliding-window layers need ``window + 1`` rows even when
        ``max_len`` is shorter (the window is enforced by masking)."""
        buf = self.max_len
        if "L" in cfg.pattern:
            buf = max(buf, cfg.sliding_window + 1)
        return buf

    def blocks_per_slot(self, cfg) -> int:
        return -(-self.buffer_len(cfg) // self.page_size)

    def resolve_pages(self, cfg) -> int:
        if self.num_pages is not None:
            return self.num_pages
        return self.num_slots * self.blocks_per_slot(cfg)

    def resolved_kv_dtype(self, cfg):
        return self.kv_dtype if self.kv_dtype is not None else cfg.compute_dtype

    def bytes_per_token(self, cfg) -> int:
        """Pool bytes one cached token costs across all attention layers
        (k + v rows, plus the per-row f32 scales for int8 pages)."""
        itemsize = jnp.zeros((), self.resolved_kv_dtype(cfg)).dtype.itemsize
        per_tok = 2 * cfg.n_kv_heads * cfg.hd * itemsize  # k + v
        if self.kv_dtype == "int8":
            per_tok += 2 * cfg.n_kv_heads * 4  # k_scale + v_scale rows
        n_attn = sum(1 for k in cfg.pattern if k in "GLB")
        return per_tok * n_attn

    def bytes_per_page(self, cfg) -> int:
        return self.page_size * self.bytes_per_token(cfg)

    def pages_for_bytes(self, cfg, budget_bytes: int) -> int:
        """Pages a fixed HBM budget buys under this spec's dtype — the
        admission ceiling.  int8 pages cost roughly half the bytes of
        bf16 ones, so the same budget admits ~2x the requests."""
        return budget_bytes // self.bytes_per_page(cfg)

    def memory_bytes(self, cfg) -> int:
        """Cache bytes this spec allocates (all layers)."""
        if self.layout == "paged":
            return self.resolve_pages(cfg) * self.bytes_per_page(cfg)
        return self.num_slots * self.buffer_len(cfg) * self.bytes_per_token(cfg)

    def build(self, params: PyTree, cfg) -> "KVCache":
        return KVCache(self, params, cfg)


class KVCache:
    """Host handle pairing a :class:`KVState` with its page bookkeeping.

    The engine threads ``kv.state`` through the jitted step and calls the
    mutating methods (``admit_slot`` / ``share`` / ``prepare_step`` /
    ``free_slot`` / ``fork_slot``) between steps; every mutator keeps the
    device block-table array in sync.  For the dense layout all of them
    are no-ops — the two layouts are drop-in interchangeable.
    """

    def __init__(self, spec: KVCacheSpec, params: PyTree, cfg):
        self.spec = spec
        self.cfg = cfg
        self._dirty = False
        data = spec.layout_cls.build_data(spec, params, cfg)
        if spec.layout == "paged":
            self.tables: Optional[PagedTables] = PagedTables(
                spec.num_slots,
                spec.blocks_per_slot(cfg),
                spec.resolve_pages(cfg),
                spec.page_size,
            )
            self._state = KVState(
                data=data,
                tables=jnp.asarray(self.tables.device_tables()),
                page_size=spec.page_size,
            )
        else:
            self.tables = None
            self._state = KVState(data=data, tables=None, page_size=0)

    @property
    def state(self) -> KVState:
        """Device KV state.  Host-side table mutations are synced lazily:
        the device array is rebuilt and uploaded once per read after any
        number of admits/shares/frees, not once per mutation."""
        if self._dirty:
            self._state = dataclasses.replace(
                self._state, tables=jnp.asarray(self.tables.device_tables())
            )
            self._dirty = False
        return self._state

    @state.setter
    def state(self, new: KVState) -> None:
        self._state = new

    # -- layout-independent surface ----------------------------------------

    @property
    def has_recurrent(self) -> bool:
        """True when the pattern carries per-slot recurrent state leaves."""
        return bool(set(self.cfg.pattern) & {"R", "M"})

    @property
    def page_size(self) -> int:
        return self.spec.page_size if self.tables is not None else 0

    @property
    def num_pages(self) -> int:
        return self.tables.num_pages if self.tables is not None else 0

    @property
    def used_pages(self) -> int:
        return self.tables.used_pages if self.tables is not None else 0

    def memory_bytes(self) -> int:
        return sum(x.nbytes for x in jax.tree_util.tree_leaves(self._state.data))

    def sync(self) -> None:
        """Mark the device block tables stale; the next ``state`` read
        rebuilds and uploads them (no-op for dense)."""
        if self.tables is not None:
            self._dirty = True

    def reset_accounting(self) -> None:
        """Rebaseline the page-usage counters (``touched_pages``) without
        dropping live or prefix-cached pages — what ``reset_stats`` calls
        so a warmed-up engine records only post-reset page traffic
        (no-op for dense)."""
        if self.tables is not None:
            self.tables.reset_touched()

    def check_invariants(self) -> None:
        """Page-accounting invariants (``PagedTables.check_invariants``;
        no-op for dense).  An idle engine — no request holding a slot —
        must also show ``used_pages == 0``: any still-referenced page is
        a leak (a cancel or free path that forgot a decref).  The traffic
        harness asserts exactly that after every replay drains."""
        if self.tables is not None:
            self.tables.check_invariants()

    # -- mutators (no-ops for DenseSlots) -----------------------------------

    def admit_slot(self, slot: int, prompt, max_new: int) -> Optional[int]:
        """Reserve pages for a request; returns prompt tokens covered by
        shared prefix pages (skip prefilling them), or None when the pool
        cannot hold the request.  Dense: always admits, shares nothing.
        Recurrent-state rows are zeroed for the slot in both layouts (the
        previous tenant's state must not seed the new request)."""
        if self.tables is None:
            if self.has_recurrent:
                self.state = dataclasses.replace(
                    self.state,
                    data=reset_recurrent_state(self.state.data, [slot]),
                )
            return 0
        shared = self.tables.admit(slot, prompt, max_new)
        if shared is not None:
            if self.has_recurrent:
                self.state = dataclasses.replace(
                    self.state,
                    data=reset_recurrent_state(self.state.data, [slot]),
                )
            self.sync()
        return shared

    def probe_shared(self, prompt) -> int:
        """Prompt tokens the prefix cache could supply right now, without
        mutating anything (the admission-time in-flight dedup probe).
        Dense: nothing is ever shared.  Recurrent patterns: never —
        prefix sharing is attention-only (see ``share``)."""
        if self.tables is None or self.has_recurrent:
            return 0
        return self.tables.probe_shareable(prompt)

    def share(self, slot: int, prompt, pos: int) -> int:
        """Map prefix-cache pages covering ``prompt`` from ``pos`` on.
        Disabled for recurrent patterns: a shared page lets the engine
        *skip prefilling* those tokens, which is only sound when the
        cache is an append-only log — the 'R'/'M' carried state must
        scan every prompt token, so nothing is shared (or published;
        see ``register_prompt_pages``) and every prompt prefills in
        full."""
        if self.tables is None or self.has_recurrent:
            return 0
        n = self.tables.try_share(slot, prompt, pos)
        if n:
            self.sync()
        return n

    def prepare_step(self, grants) -> None:
        """Allocate/COW the pages the step's grants will write, apply any
        copy-on-write page copies device-side, sync the tables."""
        if self.tables is None:
            return
        ops = []
        for slot, pos0, toks in grants:
            ops += self.tables.prepare_write(slot, pos0, len(toks))
        if ops:
            self.state = copy_pages_state(self.state, ops)
        self.sync()

    def prepare_write(self, slot: int, start: int, n: int) -> None:
        self.prepare_step([(slot, start, [0] * n)])

    def register_prompt_pages(self, slot: int, prompt, upto: int) -> None:
        """Publish fully-written prompt pages into the prefix cache.
        Recurrent patterns publish nothing — keeping the prefix cache
        empty is what guarantees ``admit`` never maps shared pages for
        them (one gate covers admission, lazy sharing, and probing)."""
        if self.tables is not None and not self.has_recurrent:
            self.tables.register_prompt_pages(slot, prompt, upto)

    def trim_slot(self, slot: int, keep_tokens: int) -> int:
        """Roll back ``slot`` to ``keep_tokens`` positions: drop the blocks
        past the kept length (speculative-decoding rollback of rejected
        draft KV).  Dense layout: a no-op — stale rows past the position
        cursor are never attended (position-mask trim is free).  Returns
        blocks dropped.  Recurrent patterns refuse: carried state has
        already consumed the trimmed tokens and cannot roll back."""
        if self.has_recurrent:
            raise UnsupportedPatternError(
                "trim_slot cannot roll back recurrent state ('R'/'M' "
                "layers): the carried state already consumed the trimmed "
                "tokens — speculative rollback is attention-only"
            )
        if self.tables is None:
            return 0
        n = self.tables.trim(slot, keep_tokens)
        if n:
            self.sync()
        return n

    def free_slot(self, slot: int) -> None:
        if self.tables is not None:
            self.tables.free_slot(slot)
            self.sync()

    def fork_slot(self, parent: int, child: int) -> None:
        """Share every page of ``parent`` with ``child`` (copy-on-write on
        the next write).  Dense layout: unsupported.  Recurrent leaves are
        *copied* eagerly, not shared — a recurrent row is overwritten in
        place by the child's very next step, so page-style COW degenerates
        to a copy anyway; doing it here keeps the divergence explicit."""
        if self.tables is None:
            raise NotImplementedError("fork_slot requires the paged layout")
        self.tables.fork(parent, child)
        if self.has_recurrent:
            self.state = dataclasses.replace(
                self.state,
                data=copy_recurrent_state(self.state.data, parent, child),
            )
        self.sync()
