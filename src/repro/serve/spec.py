"""``repro.serve.spec`` — speculative decoding on the serving engine.

Decode is the engine's remaining straggler: one token per request per
step, so long generations dominate wall time the way long prompts did
before chunked prefill.  Speculative decoding is the decode-side
analogue of the per-step token budget (DropCompute's ``tau`` applied to
serving): a cheap **proposer** guesses ``k`` tokens per decode slot, the
target model **verifies** all of them in one bounded mixed step — the
same shape-stable ``prefill_chunk``/``packed_prefill`` program family
that runs chunked prefill (the contract is documented, and exposed for
direct callers, as ``models.model.verify_step``) — and the engine keeps
the longest greedy-matching prefix plus one bonus token.
Per-token latency variance becomes a bounded verify step plus a
stochastic acceptance count, and the emitted stream is **token-identical
to the non-speculative greedy oracle by construction**: every emitted
token is the target model's argmax given the accepted history, whatever
the proposer guessed.

Rollback of rejected drafts rides PR 4's cache machinery: dense slots
need nothing (stale rows past the position cursor are never attended —
position-mask trim), the paged layout drops the overshot blocks via
``KVCache.trim_slot`` (the ``fork_slot``/COW allocator already keeps
shared pages safe: a verify write never lands in a page another slot can
see).

Two proposers ship:

* :class:`NGramProposer` — prompt-lookup decoding: match the slot's most
  recent n-gram earlier in its own token history (prompt + output) and
  propose the continuation.  Free (no second model), and strong on
  repetitive or self-repeating streams — which greedy decode produces a
  lot of.
* :class:`DraftModelProposer` — a second, smaller model (its own
  ``ModelConfig`` + params) runs ahead autoregressively on its own dense
  KV cache, mirroring the engine's slots.  Rollback on the draft side is
  again a free position-mask trim.

The engine drives either through the same three calls:
``propose_batch`` before scheduling, acceptance after the verify step,
``free_slot`` when a request leaves its slot.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ModelConfig
from ..models.model import init_decode_cache, prefill_chunk, require_chunkable

#: one proposer ask: (slot index, token history = prompt + output, max k)
Ask = Tuple[int, List[int], int]


def accept_sampled(
    draft: Sequence[int], sampled: Sequence[int]
) -> Tuple[int, List[int]]:
    """Rejection-sampling acceptance against the target's *sampled*
    verify columns.

    ``sampled`` is the verify step's per-column sampled token for one
    slot (length ``1 + len(draft)``): column ``j`` is the token the
    target model samples — with the request's own ``SamplingParams`` and
    the per-position key for output index ``base + j``
    (``serve.sampling``) — after consuming the grant through column
    ``j``.  Draft ``j`` is accepted iff it equals that sample; the first
    mismatching (or final) column supplies the bonus/resampled token.
    Returns ``(n_accepted, emitted)`` with
    ``emitted == sampled[: n_accepted + 1]``.

    This *is* the rejection-sampling rule (accept draft ``d`` with
    probability ``min(1, p(d)/q(d))``, resample from the residual
    ``norm(max(p - q, 0))`` on rejection) for the deterministic
    proposers the engine ships, whose draft distribution ``q`` is a
    point mass at ``d``: sampling ``x ~ p`` once and accepting iff
    ``x == d`` accepts with probability ``p(d) = min(1, p(d)/q(d))``,
    and on rejection emits ``x`` distributed as ``p`` conditioned on
    ``x != d`` — exactly the normalized residual.  (A stochastic
    proposer exposing its full ``q`` would use
    ``serve.sampling.residual_sample``; the ``Proposer`` API currently
    returns tokens only, i.e. one-hot ``q``.)  The coupling buys more
    than distribution-exactness: because column ``j``'s key depends only
    on (request seed, output index), the sample at any column whose
    history matches the non-speculative stream *is* that stream's next
    token — so speculative streams are realization-identical to the
    non-speculative sampled engine, whatever the proposer guesses.

    With greedy params (``temperature == 0``) every sampled column is
    the argmax column and this reduces to the pre-sampling
    ``accept_greedy`` byte-for-byte.
    """
    a = 0
    while a < len(draft) and int(draft[a]) == int(sampled[a]):
        a += 1
    return a, [int(t) for t in sampled[: a + 1]]


def accept_greedy(
    draft: Sequence[int], greedy: Sequence[int]
) -> Tuple[int, List[int]]:
    """Longest greedy-matching draft prefix.

    ``greedy`` is the verify step's per-column argmax for one slot
    (length ``1 + len(draft)``): column ``j`` is the target's next token
    after consuming the grant through column ``j``.  Draft ``j`` is
    accepted iff it equals column ``j``'s argmax (the token the target
    would have emitted at that point); the first mismatching — or final —
    column supplies the bonus token.  Returns ``(n_accepted, emitted)``
    with ``emitted == greedy[: n_accepted + 1]``, i.e. 1..k+1 tokens, all
    of them exactly what non-speculative greedy decoding would emit.

    The ``temperature == 0`` case of :func:`accept_sampled`, kept as the
    named contract for greedy callers and tests.
    """
    return accept_sampled(draft, greedy)


class Proposer:
    """Draft-token source for speculative decoding.

    ``propose_batch`` receives every decode slot's ask for the coming
    engine step and returns per-slot draft tokens (possibly fewer than
    asked, possibly none — an empty draft degrades that slot to a plain
    decode token).  Proposers may keep per-slot state; ``free_slot`` is
    called when a request leaves its slot.
    """

    name = "null"

    def bind_engine(self, batch_slots: int, max_len: int) -> None:
        """Called once at engine construction with the engine's geometry;
        stateful proposers validate theirs covers it (fail at
        construction, not with an IndexError mid-serving)."""

    def propose_batch(self, asks: Sequence[Ask]) -> Dict[int, List[int]]:
        return {}

    def free_slot(self, slot: int) -> None:  # pragma: no cover - stateless
        pass


class NGramProposer(Proposer):
    """Prompt-lookup decoding: propose the continuation of the most recent
    earlier occurrence of the history's trailing n-gram.

    Tries the longest n-gram first (``max_ngram`` down to ``min_ngram``)
    and scans the history right-to-left, so the most specific, most
    recent match wins.  No model, no state — acceptance does all the
    quality control.
    """

    name = "ngram"

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got {min_ngram}..{max_ngram}"
            )
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose_batch(self, asks: Sequence[Ask]) -> Dict[int, List[int]]:
        return {slot: self.propose(hist, k) for slot, hist, k in asks if k > 0}

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        hist = list(history)
        n_hist = len(hist)
        for n in range(min(self.max_ngram, n_hist - 1), self.min_ngram - 1, -1):
            suffix = hist[n_hist - n :]
            for start in range(n_hist - n - 1, -1, -1):
                if hist[start : start + n] == suffix:
                    cont = hist[start + n : start + n + k]
                    if cont:
                        return [int(t) for t in cont]
        return []


@functools.partial(jax.jit, static_argnames=("cfg",))
def _draft_step(params, cfg: ModelConfig, cache, tokens, pos, lens):
    """Draft-model step, jitted per (cfg, shape): the catch-up chunked
    prefill and the one-token-wide decode loop both land here."""
    return prefill_chunk(params, cfg, cache, tokens, pos, lens, moe_impl="dense")


class DraftModelProposer(Proposer):
    """Draft tokens from a second, smaller model.

    The draft model keeps its own dense KV cache with one slot per engine
    slot.  Each ``propose_batch``: (1) *catch up* — chunk-prefill the
    history tokens the draft cache hasn't seen (accepted target tokens,
    including the previous step's rejected-region overwrites); (2) *run
    ahead* — decode up to ``k`` draft tokens autoregressively, writing
    their KV past the history.  The run-ahead KV is speculative by
    definition, so the per-slot cursor stays at the history length:
    whatever the target accepts arrives as next step's catch-up delta and
    overwrites the speculated rows (dense position-mask rollback — stale
    rows are never attended).

    Compiled shapes: one ``(B, chunk_size)`` catch-up program and one
    ``(B, 1)`` decode program, both per draft config — the same
    shape-stability story as the engine itself.
    """

    name = "draft"

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        batch_slots: int,
        max_len: int,
        chunk_size: int = 32,
    ):
        require_chunkable(cfg, "DraftModelProposer")
        if batch_slots < 1 or max_len < 1 or chunk_size < 1:
            raise ValueError("batch_slots, max_len, chunk_size must be >= 1")
        self.params = params
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.chunk_size = chunk_size
        self.cache = init_decode_cache(
            params, cfg, batch_slots, max_len, linear=True
        )
        self._pos = [0] * batch_slots  # history tokens the draft cache holds
        # the tokens those cache rows were actually written from — the
        # recycled-slot guard.  Comparing only ``_pos[s] > len(h)`` is not
        # enough: a recycled slot whose *new* request has a longer history
        # than the old cursor would skip prefilling the real prefix and
        # catch up from stale KV (wrong drafts, silently — acceptance
        # still keeps outputs correct, but the draft hit rate collapses).
        self._hist: List[List[int]] = [[] for _ in range(batch_slots)]

    def bind_engine(self, batch_slots: int, max_len: int) -> None:
        if batch_slots > self.batch_slots or max_len > self.max_len:
            raise ValueError(
                f"DraftModelProposer(batch_slots={self.batch_slots}, "
                f"max_len={self.max_len}) cannot cover an engine with "
                f"batch_slots={batch_slots}, max_len={max_len}"
            )

    def free_slot(self, slot: int) -> None:
        # the cache rows need no clearing: the next request's catch-up
        # overwrites from position 0 and masking hides the rest
        self._pos[slot] = 0
        self._hist[slot] = []

    def propose_batch(self, asks: Sequence[Ask]) -> Dict[int, List[int]]:
        asks = [
            (s, h, min(k, self.max_len - len(h)))
            for s, h, k in asks
            if k > 0 and len(h) < self.max_len
        ]
        asks = [(s, h, k) for s, h, k in asks if k > 0]
        if not asks:
            return {}
        for s, h, _ in asks:
            # Recycled-slot / divergent-history guard: rewind the cursor
            # to the longest prefix of ``h`` the cache rows were really
            # written from.  Catches the case ``free_slot`` handles (and
            # a missed ``free_slot``, e.g. a proposer reused across
            # engines) *including* a new request whose history is longer
            # than the stale cursor — ``_pos[s] > len(h)`` alone missed
            # that one and caught up from another request's KV.
            held = self._hist[s]
            m = 0
            limit = min(self._pos[s], len(held), len(h))
            while m < limit and held[m] == h[m]:
                m += 1
            if m < self._pos[s]:
                self._pos[s] = m
                self._hist[s] = held[:m]

        b = self.batch_slots
        # 1) catch up on unseen history; the chunk containing each slot's
        # final history token yields its first draft token
        seed: Dict[int, int] = {}
        while True:
            tokens = np.zeros((b, self.chunk_size), np.int32)
            pos = np.zeros((b,), np.int32)
            lens = np.zeros((b,), np.int32)
            finishing: List[int] = []
            for s, h, _ in asks:
                delta = len(h) - self._pos[s]
                if delta == 0:
                    continue
                n = min(delta, self.chunk_size)
                tokens[s, :n] = h[self._pos[s] : self._pos[s] + n]
                pos[s] = self._pos[s]
                lens[s] = n
                self._pos[s] += n
                self._hist[s] = list(h[: self._pos[s]])
                if n == delta:
                    finishing.append(s)
            if not lens.any():
                break
            logits, self.cache = _draft_step(
                self.params, self.cfg, self.cache, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(lens),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1))  # (B, C) — syncs
            for s in finishing:
                seed[s] = int(nxt[s, int(lens[s]) - 1])

        # 2) run ahead: up to max(k) one-token decode steps, all slots
        # advancing together; each slot stops contributing past its k
        drafts: Dict[int, List[int]] = {s: [seed[s]] for s, _, _ in asks}
        max_k = max(k for _, _, k in asks)
        cursor = {s: len(h) for s, h, _ in asks}
        for j in range(max_k - 1):
            tokens = np.zeros((b, 1), np.int32)
            pos = np.zeros((b,), np.int32)
            lens = np.zeros((b,), np.int32)
            active = [
                (s, k) for s, _, k in asks
                if len(drafts[s]) < k and cursor[s] < self.max_len
            ]
            if not active:
                break
            for s, _ in active:
                tokens[s, 0] = drafts[s][-1]
                pos[s] = cursor[s]
                lens[s] = 1
            logits, self.cache = _draft_step(
                self.params, self.cfg, self.cache, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(lens),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s, _ in active:
                drafts[s].append(int(nxt[s, 0]))
                cursor[s] += 1
        # the run-ahead rows are speculative: leave _pos at the history
        # length so next step's catch-up overwrites them
        return drafts


@dataclasses.dataclass
class SpecConfig:
    """Speculative-decoding knobs for ``ContinuousBatcher``.

    proposer: draft source (``NGramProposer`` / ``DraftModelProposer`` /
      any :class:`Proposer`).
    k: max draft tokens verified per decode slot per step.  The verify
      grant is ``1 + accepted_drafts`` cache writes and ``accepted + 1``
      emitted tokens; draft tokens are scheduled *under the engine's
      token budget* (decode baselines stay unconditional), so ``tau``
      bounds the verify step exactly like it bounds prefill chunks.
    """

    proposer: Proposer
    k: int = 4

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"SpecConfig.k must be >= 1, got {self.k}")
        if not isinstance(self.proposer, Proposer):
            raise TypeError(
                f"proposer must be a repro.serve.spec.Proposer, got "
                f"{type(self.proposer).__name__}"
            )
