"""``repro.serve.sampling`` — per-request stochastic decode for the engine.

Decode was greedy argmax everywhere; every realism-sensitive workload
(traffic replay, best-of-n, any user-facing serving) runs a distribution
a production engine would never serve.  This module is the sampling half
of the fix: a frozen :class:`SamplingParams` per request (temperature /
top-k / top-p / seed) and one jitted sampler, :func:`sample_tokens`, that
every step path — dense ``(B, C)``, packed ``(capacity,)``, paged —
feeds its logits through instead of ``jnp.argmax``.

Design constraints, in order:

* **Per-request, per-position PRNG keys.**  Output token ``i`` of a
  request with seed ``s`` is sampled with ``fold_in(PRNGKey(s), i)`` — a
  pure function of the request's seed and the token's *output index*.
  No engine state (step counter, slot index, batch composition, packed
  offset) enters the key, which is what makes streams reproducible
  across engine restarts, identical across the dense/packed/paged step
  programs, identical with speculation on or off, and identical to a
  single-request reference loop (:func:`sample_one`) with the same seed.
* **``temperature == 0`` is byte-identical greedy.**  The sampler
  computes the raw-logits argmax alongside the stochastic draw and
  selects per row with ``jnp.where(t > 0, ...)`` — the default
  ``SamplingParams()`` reproduces the pre-sampling engine exactly, which
  is what keeps every greedy parity suite (and the speculative
  token-identity guarantee) intact.
* **No extra host sync.**  Sampling happens inside the same jitted
  dispatch chain as the step; the one blocking ``np.asarray`` per step
  moves from the argmax result to the sampled result.  The dispatcher
  specializes on the *host-side* per-row param arrays (which the
  scheduler builds from request fields — no device value is inspected):
  an all-greedy step pays exactly one argmax, a sampled step without
  truncation skips the threshold search, and only steps where some row
  asks for top-k/top-p run the full kernel.  A row's realized token is
  identical whichever kernel serves it (an untruncated row's keep-mask
  is all-ones, and the Gumbel draw depends only on the row's key).

Sampling itself is Gumbel-max over masked, temperature-scaled logits:
top-k keeps the ``k`` highest-scoring tokens (``0`` disables), top-p
keeps the smallest prefix of the probability-sorted vocabulary whose
cumulative mass reaches ``top_p`` (exclusive cumsum, so the highest-
probability token always survives — ``top_p`` arbitrarily small degrades
to greedy, never to an empty support).  Both filters reduce to *value
thresholds* (ties with the boundary value are kept, so the kept set is a
pure function of each token's score): the kernel finds those thresholds
by a fixed 32-step bisection on the monotone unsigned-bit encoding of
the float32 scores — exact, branch-free, and O(V) work per step —
instead of sorting the vocabulary, because XLA's CPU sort is tens of
milliseconds at serving shapes while 32 masked reductions fuse into
well under one.  The Gumbel-max form matters for speculation: the
verify step samples every draft column with that column's own
output-index key, and ``spec.accept_sampled`` turns those per-column
samples into rejection-sampling acceptance (see its docstring for the
coupling argument).

:func:`residual_sample` is the general rejection-sampling residual
``norm(max(p - q, 0))`` for proposers that expose a full draft
distribution ``q``; the in-tree proposers are deterministic (one-hot
``q``), for which the coupled form in ``accept_sampled`` is exact and
keeps streams realization-identical to the non-speculative engine.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request stochastic-decode knobs.

    temperature: ``0`` = greedy argmax, byte-identical to the
      pre-sampling engine (the default); ``> 0`` scales the logits
      before filtering and categorical sampling.
    top_k: keep only the ``k`` highest-probability tokens (``0`` =
      disabled).
    top_p: nucleus filtering — keep the smallest probability-sorted
      token set whose cumulative mass reaches ``top_p`` (``1.0`` =
      disabled; the top token always survives).
    seed: base of the request's key stream.  Output token ``i`` is
      sampled with ``fold_in(PRNGKey(seed), i)``: identical seeds replay
      identical streams across engine restarts and across the
      dense/packed/paged step programs, and two requests with distinct
      seeds draw independent streams even inside one batched step.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        # raised, never assert-ed (asserts vanish under python -O and a
        # NaN temperature would serve garbage tokens, not an error)
        if not (isinstance(self.temperature, (int, float))
                and math.isfinite(self.temperature) and self.temperature >= 0):
            raise ValueError(
                f"temperature must be finite and >= 0, got {self.temperature!r}"
            )
        if not (isinstance(self.top_k, (int, np.integer)) and self.top_k >= 0):
            raise ValueError(f"top_k must be an int >= 0, got {self.top_k!r}")
        if not (isinstance(self.top_p, (int, float)) and 0 < self.top_p <= 1):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p!r}")
        if not isinstance(self.seed, (int, np.integer)):
            raise ValueError(f"seed must be an int, got {self.seed!r}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0

    def with_seed(self, seed: int) -> "SamplingParams":
        return dataclasses.replace(self, seed=int(seed))


#: the default params — argmax decode, byte-identical to the engine
#: before sampling existed
GREEDY = SamplingParams()


def _row_gumbel(seed, out_idx, v):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), out_idx)
    return jax.random.gumbel(key, (v,), jnp.float32)


def _flatten_rows(logits, seeds, out_idx, temperature, top_k, top_p):
    v = logits.shape[-1]
    return (
        jnp.reshape(logits, (-1, v)).astype(jnp.float32),
        jnp.reshape(seeds, (-1,)).astype(jnp.uint32),
        jnp.reshape(out_idx, (-1,)).astype(jnp.uint32),
        jnp.reshape(temperature, (-1,)).astype(jnp.float32),
        jnp.reshape(top_k, (-1,)).astype(jnp.int32),
        jnp.reshape(top_p, (-1,)).astype(jnp.float32),
    )


def _sort_key(scaled):
    """Monotone ``float32 -> uint32`` encoding: ``a < b`` in float order
    iff ``key(a) < key(b)`` unsigned.  ``+ 0.0`` first canonicalizes
    ``-0.0`` to ``+0.0`` so float-equal scores share one key."""
    b = jax.lax.bitcast_convert_type(scaled + 0.0, jnp.uint32)
    return jnp.where(b >> 31 == 1, ~b, b | jnp.uint32(0x80000000))


def _bisect_threshold(u, predicate):
    """Largest uint32 ``s`` (per row) with ``predicate(u >= s)`` true,
    or 0 if none is — found by 32-step bisection.  ``predicate`` takes
    the ``(R, V)`` at-or-above mask and returns ``(R,)`` bool; it must
    be monotone decreasing in ``s`` (true at s=0, false at 2^32-1)."""
    r = u.shape[0]

    def body(_, state):
        lo, hi = state
        mid = lo + (hi - lo) // 2
        ok = predicate(u >= mid[:, None])
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    zero = jnp.zeros((r,), jnp.uint32)
    full = jnp.full((r,), 0xFFFFFFFF, jnp.uint32)
    lo, _ = jax.lax.fori_loop(0, 32, body, (zero, full))
    return lo


def _keep_mask(scaled, tk, tp, *, use_topk, use_topp):
    """Top-k/top-p keep-mask via threshold bisection, no sort.

    Both filters keep exactly the tokens whose score clears a per-row
    value threshold (boundary ties included): top-k's threshold is the
    kth-largest score, top-p's is the smallest score whose
    strictly-greater probability mass is still ``< top_p`` (equivalent
    to the sorted exclusive-cumsum rule, and it keeps the top token for
    any ``top_p > 0``).  Each threshold is found as a 32-step bisection
    over the unsigned-bit encoding of the scores — per step one masked
    reduction, so the whole search is O(32 V) fused work instead of an
    O(V log V) XLA sort that costs ~20ms/step on CPU at serving shapes.
    The static flags drop the bisection for a filter no row in the step
    uses (e.g. top-p-only traffic skips the top-k search entirely).

    Bisection invariants: for top-k, ``#{u >= 0} = V >= k`` and
    ``#{u >= 2^32-1} = 0 < k``, so ``lo`` converges exactly to the
    kth-largest key; for top-p, the at-or-above mass is ~1 at 0 and 0
    at 2^32-1, so ``lo`` converges to the smallest key whose mass still
    reaches ``top_p`` — and if none does (``top_p ~ 1`` vs the
    float-rounded softmax sum) it stays 0 and keeps everything, which
    is the ``top_p = 1`` contract.
    """
    v = scaled.shape[-1]
    u = _sort_key(scaled)
    keep = jnp.ones(scaled.shape, bool)
    if use_topk:
        k = jnp.clip(tk, 1, v)
        kth = _bisect_threshold(
            u, lambda m: jnp.sum(m, axis=-1) >= k
        )
        keep &= (u >= kth[:, None]) | (tk <= 0)[:, None]
    if use_topp:
        probs = jax.nn.softmax(scaled, axis=-1)
        pth = _bisect_threshold(
            u, lambda m: jnp.sum(jnp.where(m, probs, 0.0), axis=-1) >= tp
        )
        keep &= u >= pth[:, None]
    return keep


@jax.jit
def _greedy_tokens(logits):
    v = logits.shape[-1]
    return jnp.argmax(
        jnp.reshape(logits, (-1, v)).astype(jnp.float32), axis=-1
    ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("use_topk", "use_topp"))
def _sampled_tokens(logits, seeds, out_idx, temperature, top_k, top_p,
                    *, use_topk, use_topp):
    lg, seeds, oidx, t, tk, tp = _flatten_rows(
        logits, seeds, out_idx, temperature, top_k, top_p
    )
    v = lg.shape[-1]
    greedy_tok = jnp.argmax(lg, axis=-1)
    scaled = lg / jnp.where(t > 0, t, 1.0)[:, None]
    if use_topk or use_topp:
        mask = _keep_mask(scaled, tk, tp, use_topk=use_topk,
                          use_topp=use_topp)
        masked = jnp.where(mask, scaled, -jnp.inf)
    else:
        masked = scaled
    g = jax.vmap(_row_gumbel, in_axes=(0, 0, None))(seeds, oidx, v)
    stoch = jnp.argmax(masked + g, axis=-1)
    return jnp.where(t > 0, stoch, greedy_tok).astype(jnp.int32)


def sample_tokens(logits, seeds, out_idx, temperature, top_k, top_p):
    """Sample one token per logits row, engine-style.

    ``logits`` is ``(..., V)``; the per-row params ``seeds`` (uint32),
    ``out_idx`` (the row's output index — the fold_in data), and
    ``temperature`` / ``top_k`` / ``top_p`` all carry the matching
    leading shape.  Rows with ``temperature == 0`` return the raw-logits
    argmax (byte-identical greedy); stochastic rows draw Gumbel-max over
    the top-k/top-p-masked, temperature-scaled logits.  Returns int32
    tokens with the leading shape.

    The per-row params are host arrays the scheduler builds from request
    fields, so dispatch specializes on them without any device sync: an
    all-greedy step is exactly one jitted argmax (the pre-sampling
    engine's cost), truncation-free sampling skips the threshold search,
    and the full kernel runs only when some sampled row asks for
    top-k/top-p.  Which kernel serves a row never changes its realized
    token (untruncated keep-masks are all-ones; keys don't depend on
    batch composition).

    All sampling math runs in float32 regardless of the model's compute
    dtype (bfloat16 logits upcast exactly, so the greedy argmax is
    unchanged by the cast).
    """
    lead = logits.shape[:-1]
    t = np.asarray(temperature)
    sampled = t > 0
    if not sampled.any():
        out = _greedy_tokens(logits)
    else:
        out = _sampled_tokens(
            logits, seeds, out_idx, temperature, top_k, top_p,
            use_topk=bool((sampled & (np.asarray(top_k) > 0)).any()),
            use_topp=bool((sampled & (np.asarray(top_p) < 1.0)).any()),
        )
    return jnp.reshape(out, lead)


def sample_one(logits, params: SamplingParams, out_idx: int) -> int:
    """Sample output token ``out_idx`` from one ``(V,)`` logits row
    exactly the way the engine does — the single-request reference the
    batched parity tests pin against."""
    row = jnp.reshape(jnp.asarray(logits), (1, -1))
    tok = sample_tokens(
        row,
        np.asarray([params.seed & 0xFFFFFFFF], np.uint32),
        np.asarray([max(int(out_idx), 0)], np.int32),
        np.asarray([params.temperature], np.float32),
        np.asarray([params.top_k], np.int32),
        np.asarray([params.top_p], np.float32),
    )
    return int(tok[0])


@jax.jit
def residual_sample(target_logits, draft_probs, key):
    """Sample from the rejection-sampling residual ``norm(max(p - q, 0))``.

    The general speculative-acceptance form: draft token ``d ~ q`` is
    accepted with probability ``min(1, p(d) / q(d))``; on rejection the
    emitted token is drawn from the residual distribution this function
    samples, and the marginal over accept/reject is exactly ``p``.  The
    in-tree proposers are deterministic (``q`` is a point mass), where
    the coupled per-column form in ``spec.accept_sampled`` realizes the
    same rule without a second draw; this utility is for stochastic
    proposers that expose their full ``q``.

    ``target_logits`` is ``(..., V)`` raw target logits, ``draft_probs``
    the proposer's ``(..., V)`` probabilities, ``key`` a JAX PRNG key.
    Degenerate residuals (``q == p`` exactly) fall back to sampling
    ``p`` itself.  Returns int32 tokens with the leading shape.
    """
    p = jax.nn.softmax(target_logits.astype(jnp.float32), axis=-1)
    r = jnp.maximum(p - draft_probs.astype(jnp.float32), 0.0)
    z = jnp.sum(r, axis=-1, keepdims=True)
    r = jnp.where(z > 0, r / jnp.where(z > 0, z, 1.0), p)
    logr = jnp.where(r > 0, jnp.log(jnp.maximum(r, 1e-38)), -jnp.inf)
    return jax.random.categorical(key, logr, axis=-1).astype(jnp.int32)
