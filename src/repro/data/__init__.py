from .synthetic import DataConfig, DataStream, batch_at, compute_cost_proxy, microbatches_at

__all__ = ["DataConfig", "DataStream", "batch_at", "compute_cost_proxy", "microbatches_at"]
