"""Deterministic synthetic data pipeline with realistic length variability.

The paper's compute-variance story is driven by *dynamic sequence lengths*
(appendix A.1/B.1): user-post lengths follow a log-normal distribution
[Sobkowicz et al. 2013].  This pipeline generates token streams whose
document lengths are log-normal, and offers the two standard batching
strategies the paper discusses:

  * ``pad``  — one document per row, padded to seq_len (wasted compute,
    but per-row compute varies with true length -> compute variance);
  * ``pack`` — documents concatenated and chunked to fixed seq_len
    [Kosec et al. 2021] (uniform compute, the "engineering fix" whose
    cost DropCompute avoids).

Data is sampled from a Zipf-ish unigram distribution with a deterministic
per-(epoch, step, worker) PRNG so every worker/shard regenerates its exact
shard without any coordination — the pipeline is stateless and resumable
from a step counter (checkpoint-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 512
    batch_size: int = 8  # per-step global batch
    strategy: str = "pack"  # pack | pad
    # log-normal document lengths (tokens)
    len_mean: float = 180.0
    len_sigma: float = 1.0
    seed: int = 0
    # learnable-structure knob: 0 = iid unigrams, >0 = kth-order repeats so
    # tiny models actually have something to learn in convergence tests.
    structure: float = 0.5


def _doc_lengths(rng: np.random.Generator, n: int, cfg: DataConfig) -> np.ndarray:
    sig2 = np.log(1.0 + cfg.len_sigma)
    mu = np.log(cfg.len_mean) - sig2 / 2
    return np.clip(rng.lognormal(mu, np.sqrt(sig2), size=n).astype(np.int64), 4, cfg.seq_len)


def _sample_tokens(rng: np.random.Generator, n: int, cfg: DataConfig) -> np.ndarray:
    # Zipf unigram over the vocab
    ranks = np.arange(1, cfg.vocab_size + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(cfg.vocab_size, size=n, p=probs)
    if cfg.structure > 0:
        # Make token t+1 depend on t for a fraction of positions: y = (x*7+3)%V
        dep = rng.random(n) < cfg.structure
        toks[1:] = np.where(dep[1:], (toks[:-1] * 7 + 3) % cfg.vocab_size, toks[1:])
    return toks.astype(np.int32)


def batch_at(step: int, cfg: DataConfig, worker: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic batch for (step, worker): {'tokens', 'weights', 'lengths'}."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, worker, step]))
    b, s = cfg.batch_size, cfg.seq_len
    if cfg.strategy == "pack":
        tokens = _sample_tokens(rng, b * s, cfg).reshape(b, s)
        weights = np.ones((b, s), np.float32)
        lengths = np.full((b,), s, np.int64)
    else:
        lengths = _doc_lengths(rng, b, cfg)
        tokens = np.zeros((b, s), np.int32)
        weights = np.zeros((b, s), np.float32)
        for i, ln in enumerate(lengths):
            tokens[i, :ln] = _sample_tokens(rng, int(ln), cfg)
            weights[i, :ln] = 1.0
    return {"tokens": tokens, "weights": weights, "lengths": lengths}


def microbatches_at(step: int, cfg: DataConfig, m: int, worker: int = 0) -> Dict[str, np.ndarray]:
    """Batch reshaped to M micro-batches: leaves get leading dim M."""
    assert cfg.batch_size % m == 0, (cfg.batch_size, m)
    b = batch_at(step, cfg, worker)
    mb = cfg.batch_size // m
    return {
        "tokens": b["tokens"].reshape(m, mb, cfg.seq_len),
        "weights": b["weights"].reshape(m, mb, cfg.seq_len),
    }


def compute_cost_proxy(lengths: np.ndarray, seq_len: int, strategy: str) -> float:
    """Relative compute of a batch (1.0 = fully packed).  With 'pad', true
    compute tracks sum(lengths)/(B*S) — the source of compute variance."""
    if strategy == "pack":
        return 1.0
    return float(lengths.sum() / (lengths.shape[0] * seq_len))


class DataStream:
    """Iterator facade used by the trainer."""

    def __init__(self, cfg: DataConfig, microbatches: Optional[int] = None, worker: int = 0):
        self.cfg = cfg
        self.m = microbatches
        self.worker = worker
        self.step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self.m is None:
            b = batch_at(self.step, self.cfg, self.worker)
        else:
            b = microbatches_at(self.step, self.cfg, self.m, self.worker)
        self.step += 1
        return b
