"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU,
where the kernels lower to Mosaic.  The pure-jnp oracles live in ref.py;
tests sweep shapes/dtypes asserting allclose between the two.
"""
from __future__ import annotations

import functools

import jax

from .flash_attention import (
    flash_attention as _flash,
    paged_attention_xla as _paged_xla,
    paged_flash_attention as _paged_flash,
)
from .masked_accum import masked_accum as _maccum, masked_accum_tree as _maccum_tree
from .rmsnorm import rmsnorm as _rmsnorm
from .ssd_chunk import ssd_chunk as _ssd_chunk, ssd_segment as _ssd_segment
from . import ref as _ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal=True, window=0, block_q=128, block_k=128,
                    interpret=None, q_segment_ids=None, kv_segment_ids=None):
    if interpret is None:
        interpret = _default_interpret()
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret,
                  q_segment_ids=q_segment_ids, kv_segment_ids=kv_segment_ids)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "interpret"))
def paged_flash_attention(q, k_pool, v_pool, tables, q_pos, q_slots,
                          window=0, softcap=0.0, k_scale=None, v_scale=None,
                          interpret=None):
    """Fused paged attention: Pallas kernel on TPU, fused XLA path elsewhere.

    ``interpret=None`` (the default, what the model's paged branches pass)
    picks the Pallas kernel on TPU and ``paged_attention_xla`` on other
    backends — interpret-mode Pallas walks the grid serially in Python and
    is >20x slower than the XLA lowering at serving shapes.  Pass
    ``interpret=True`` explicitly to force the interpreted kernel (the
    correctness path the kernel tests sweep).
    """
    if interpret is None:
        if _default_interpret():
            return _paged_xla(q, k_pool, v_pool, tables, q_pos, q_slots,
                              window=window, softcap=softcap,
                              k_scale=k_scale, v_scale=v_scale)
        interpret = False
    return _paged_flash(q, k_pool, v_pool, tables, q_pos, q_slots,
                        window=window, softcap=softcap,
                        k_scale=k_scale, v_scale=v_scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, eps=1e-6, block_rows=256, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def masked_accum(acc, grad, keep, scale=1.0, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _maccum(acc, grad, keep, scale=scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def masked_accum_tree(acc_tree, grad_tree, keep, scale=1.0, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _maccum_tree(acc_tree, grad_tree, keep, scale=scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, cum, b, c, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _ssd_chunk(x, dt, cum, b, c, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_segment(x, dt, cum, b, c, seg, interpret=None):
    """Segment-masked SSD term: Pallas kernel on TPU, jnp oracle elsewhere.

    Same dispatch story as ``paged_flash_attention``: interpret-mode Pallas
    walks the grid serially in Python, so off-TPU the vectorized jnp
    reference is the fast path.  Pass ``interpret=True`` to force the
    interpreted kernel (what the kernel tests sweep).
    """
    if interpret is None:
        if _default_interpret():
            return _ref.ssd_segment_ref(x, dt, cum, b, c, seg)
        interpret = False
    return _ssd_segment(x, dt, cum, b, c, seg, interpret=interpret)
