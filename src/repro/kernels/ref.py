"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, KV, Sk, D)
    v: jnp.ndarray,  # (B, KV, Sk, D)
    causal: bool = True,
    window: int = 0,
    q_segment_ids: jnp.ndarray = None,  # (B, Sq) int32
    kv_segment_ids: jnp.ndarray = None,  # (B, Sk) int32
) -> jnp.ndarray:
    """Naive attention with GQA head grouping and optional sliding window.

    Segment ids (token-packed batches): when given, query i may only
    attend key j with ``q_segment_ids[b, i] == kv_segment_ids[b, j]`` —
    requests flattened side by side into one sequence stay isolated.
    A query whose segment matches no admissible key softmaxes over an
    all-masked row (uniform weights); callers mask such rows out.
    """
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, d).astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    mask = jnp.broadcast_to(mask[None], (b, sq, sk))
    if q_segment_ids is not None:
        mask = mask & (q_segment_ids[:, :, None] == kv_segment_ids[:, None, :])
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def paged_attention_ref(
    q: jnp.ndarray,  # (T, H, D) packed query tokens
    k_pool: jnp.ndarray,  # (num_pages, page_size, KV, D)
    v_pool: jnp.ndarray,  # (num_pages, page_size, KV, D)
    tables: jnp.ndarray,  # (num_slots, num_blocks) int32
    q_pos: jnp.ndarray,  # (T,) absolute positions
    q_slots: jnp.ndarray,  # (T,) slot per query; < 0 = padding
    window: int = 0,
    softcap: float = 0.0,
    k_scale: jnp.ndarray = None,  # (num_pages, page_size, KV) f32, int8 pools
    v_scale: jnp.ndarray = None,
) -> jnp.ndarray:
    """Naive paged attention: materialize each query's logical KV buffer
    by gathering its slot's pages through the block table, then mask by
    position (causal / sliding window) and unallocated-block sentinel
    (``tables[s, b] >= num_pages``).  Padding queries return zero rows.
    int8 pools pass per-row scales; rows dequantize before the softmax."""
    t, h, d = q.shape
    num_pages, page_size, kvh, _ = k_pool.shape
    nb = tables.shape[1]
    g = h // kvh
    valid_q = q_slots >= 0
    pages = tables[jnp.clip(q_slots, 0, tables.shape[0] - 1)]  # (T, NB)
    page_ok = (pages >= 0) & (pages < num_pages)  # sentinel AND negatives
    safe = jnp.clip(pages, 0, num_pages - 1)
    keys = k_pool[safe].astype(jnp.float32)  # (T, NB, ps, KV, D)
    vals = v_pool[safe].astype(jnp.float32)
    if k_scale is not None:
        keys = keys * k_scale[safe][..., None]
        vals = vals * v_scale[safe][..., None]
    keys = keys.reshape(t, nb * page_size, kvh, d)
    vals = vals.reshape(t, nb * page_size, kvh, d)
    qg = q.reshape(t, kvh, g, d).astype(jnp.float32) / math.sqrt(d)
    logits = jnp.einsum("thgd,tkhd->thgk", qg, keys)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(nb * page_size)
    mask = (kpos[None, :] <= q_pos[:, None]) & valid_q[:, None]
    if window > 0:
        mask &= kpos[None, :] > q_pos[:, None] - window
    mask &= jnp.repeat(page_ok, page_size, axis=1)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    # re-mask after softmax: a fully-masked query (every page hostile or
    # unallocated) must output zeros, not a uniform mix of clipped rows
    w = jax.nn.softmax(logits, axis=-1) * mask[:, None, None, :]
    out = jnp.einsum("thgk,tkhd->thgd", w, vals.astype(jnp.float32))
    out = jnp.where(valid_q[:, None, None, None], out, 0.0)
    return out.reshape(t, h, d).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(
    x: jnp.ndarray,  # (B, NC, L, H, P)
    dt: jnp.ndarray,  # (B, NC, L, H)
    cum: jnp.ndarray,  # (B, NC, L, H)
    b: jnp.ndarray,  # (B, NC, L, N)
    c: jnp.ndarray,  # (B, NC, L, N)
) -> jnp.ndarray:
    """Intra-chunk SSD term (same math as models.ssm._ssd_chunked y_intra)."""
    l = x.shape[2]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    li = jnp.tril(jnp.ones((l, l), bool))[None, None, :, :, None]
    decay = jnp.exp(-jnp.where(li, diff, 0.0)) * li
    scores = jnp.einsum("bgin,bgjn->bgij", c.astype(jnp.float32), b.astype(jnp.float32))
    att = scores[..., None] * decay * dt[:, :, None, :, :]
    return jnp.einsum("bgijh,bgjhp->bgihp", att, x.astype(jnp.float32)).astype(x.dtype)


def ssd_segment_ref(
    x: jnp.ndarray,  # (T, H, P) packed tokens
    dt: jnp.ndarray,  # (T, H)
    cum: jnp.ndarray,  # (T, H) cumulative log-decay over the packed axis
    b: jnp.ndarray,  # (T, N)
    c: jnp.ndarray,  # (T, N)
    seg: jnp.ndarray,  # (T,) int32 segment (slot) ids; < 0 = padding
) -> jnp.ndarray:
    """Segment-masked SSD term for token-packed layouts.

    y[i] = sum_{j<=i, seg_j==seg_i, seg_i>=0} (C_i.B_j) exp(cum_i-cum_j) dt_j x_j

    ``cum`` is one cumulative sum over the whole packed axis: because each
    segment's tokens are contiguous (a ``pack_step`` invariant) and the
    caller zeroes dt on padding, cum_i - cum_j for a same-segment pair is
    exactly the intra-segment decay — no per-segment reset needed.
    Padding tokens (seg < 0) output zeros.
    """
    t = x.shape[0]
    diff = cum[:, None, :] - cum[None, :, :]  # (T, T, H)
    li = jnp.tril(jnp.ones((t, t), bool))
    li = li & (seg[:, None] == seg[None, :]) & (seg >= 0)[:, None]
    li = li[:, :, None]
    decay = jnp.exp(-jnp.where(li, diff, 0.0)) * li
    scores = jnp.einsum(
        "in,jn->ij", c.astype(jnp.float32), b.astype(jnp.float32)
    )
    att = scores[..., None] * decay * dt[None, :, :]
    return jnp.einsum("ijh,jhp->ihp", att, x.astype(jnp.float32)).astype(x.dtype)


def masked_accum_ref(
    acc: jnp.ndarray, grad: jnp.ndarray, keep: jnp.ndarray, scale: float = 1.0
) -> jnp.ndarray:
    """acc += keep * scale * grad  (fp32 accumulator, arbitrary grad dtype).

    The DropCompute hot loop: Algorithm 1 line 7 fused into one pass over
    the gradient buffers.
    """
    return acc + keep.astype(jnp.float32) * scale * grad.astype(jnp.float32)
