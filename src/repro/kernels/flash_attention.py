"""Flash attention Pallas TPU kernel (causal / sliding-window, GQA).

TPU-native blocking: queries are tiled to (BLOCK_Q, head_dim) VMEM tiles
and the kernel streams key/value tiles of (BLOCK_K, head_dim) through
VMEM, maintaining the online-softmax running max/sum in VREGs.  Block
sizes default to 128 to align with the MXU's 128x128 systolic array and
the (8, 128) VREG lanes.

Grid: (batch*kv_heads*q_groups, Sq / BLOCK_Q).  Each program instance owns
one query tile for one (batch, head) pair and loops over its admissible
key tiles with ``jax.lax.fori_loop`` (causal/sliding-window pruning of the
loop bounds — skipped tiles cost nothing, the TPU analogue of the CUDA
early-exit).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, *rest,
    sq, sk, block_q, block_k, causal, window, sm_scale, segmented=False,
):
    if segmented:
        q_seg_ref, kv_seg_ref, o_ref = rest
    else:
        (o_ref,) = rest
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale  # (block_q, d)

    q_start = qi * block_q
    qpos = q_start + jax.lax.iota(jnp.int32, block_q) + (sk - sq)  # right-aligned

    # Admissible key-tile range for this query tile (loop-bound pruning).
    # Segment masking composes with, but never widens, these bounds: a
    # key tile skipped by causality can hold no same-segment admissible
    # key either (segments are position-contiguous by construction).
    if causal:
        hi = jnp.minimum((q_start + block_q - 1 + (sk - sq)) // block_k + 1, sk // block_k)
    else:
        hi = sk // block_k
    if window > 0:
        lo = jnp.maximum((q_start + (sk - sq) - window + 1) // block_k, 0)
    else:
        lo = 0

    if segmented:
        qseg = q_seg_ref[...]  # (block_q,)

    def body(ki, carry):
        acc, m_prev, l_prev = carry
        k_tile = pl.load(k_ref, (pl.dslice(ki * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(ki * block_k, block_k), slice(None)))
        s = jnp.dot(q, k_tile.astype(jnp.float32).T)  # (bq, bk)

        kpos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        if segmented:
            kseg = pl.load(kv_seg_ref, (pl.dslice(ki * block_k, block_k),))
            mask &= qseg[:, None] == kseg[None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v_tile.astype(jnp.float32))
        return acc, m_cur, l_cur

    d = q_ref.shape[-1]
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, KV, Sk, D)
    v: jnp.ndarray,  # (B, KV, Sk, D)
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
    q_segment_ids: jnp.ndarray = None,  # (B, Sq) int32
    kv_segment_ids: jnp.ndarray = None,  # (B, Sk) int32
) -> jnp.ndarray:
    """Flash attention; optional segment masking for token-packed batches.

    With segment ids, query i additionally requires
    ``q_segment_ids[b, i] == kv_segment_ids[b, j]`` to attend key j — the
    mask term that keeps requests flattened side by side into one packed
    sequence from attending across their boundaries.  Segments must be
    position-contiguous (the packed layout guarantees this) so the
    causal/window loop-bound pruning stays valid; a query with no
    admissible key returns the mean of its visited value tiles (callers
    mask such padding rows out).
    """
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    segmented = q_segment_ids is not None
    if segmented != (kv_segment_ids is not None):
        # raised, not assert-ed: under python -O a half-passed pair would
        # silently disable the mask — a cross-request KV leak
        raise ValueError("pass both q_segment_ids and kv_segment_ids, or neither")

    # Flatten (B, KV, G) onto the leading grid axis; queries grouped by KV.
    qr = q.reshape(b * kvh * g, sq, d)
    kr = jnp.repeat(k.reshape(b * kvh, sk, d), g, axis=0)
    vr = jnp.repeat(v.reshape(b * kvh, sk, d), g, axis=0)

    kernel = functools.partial(
        _attn_kernel,
        sq=sq, sk=sk, block_q=block_q, block_k=block_k,
        causal=causal, window=window, sm_scale=1.0 / math.sqrt(d),
        segmented=segmented,
    )
    in_specs = [
        pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        pl.BlockSpec((None, sk, d), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((None, sk, d), lambda i, j: (i, 0, 0)),
    ]
    operands = [qr, kr, vr]
    if segmented:
        # Segment ids are per (batch, position): grid axis 0 runs over
        # b*h flattened programs, so the index map recovers the batch.
        in_specs.append(pl.BlockSpec((None, block_q), lambda i, j: (i // h, j)))
        in_specs.append(pl.BlockSpec((None, sk), lambda i, j: (i // h, 0)))
        operands.append(q_segment_ids.astype(jnp.int32))
        operands.append(kv_segment_ids.astype(jnp.int32))
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // block_q),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(b, h, sq, d)


# ---------------------------------------------------------------------------
# Paged variant: gather-by-block-table (the repro.serve.kv Paged layout)
# ---------------------------------------------------------------------------


def _paged_attn_kernel(
    q_ref, k_ref, v_ref, *rest,
    page_size, num_pages, num_blocks, window, sm_scale, softcap, quantized,
):
    if quantized:
        ks_ref, vs_ref, tbl_ref, pos_ref, slot_ref, o_ref = rest
    else:
        tbl_ref, pos_ref, slot_ref, o_ref = rest
    q = q_ref[...].astype(jnp.float32) * sm_scale  # (d,)
    pos = pos_ref[...]
    slot = slot_ref[...]
    valid_q = slot >= 0
    slot_s = jnp.maximum(slot, 0)

    # Admissible logical-block range for this query: its slot's blocks up
    # to (and including) its own position's block, lower-bounded by the
    # sliding window.  Skipped blocks cost nothing — decode reads exactly
    # ceil((pos+1)/page_size) pages, not the whole pool.
    hi = jnp.where(valid_q, jnp.minimum(pos // page_size + 1, num_blocks), 0)
    if window > 0:
        lo = jnp.maximum((pos - window + 1) // page_size, 0)
    else:
        lo = 0

    def body(bi, carry):
        acc, m_prev, l_prev = carry
        page = tbl_ref[slot_s, bi]
        # unallocated sentinel (num_pages) AND hostile negatives: a bad
        # table entry may only redirect the read to a masked tile, never
        # wrap around into another slot's pages
        ok = (page >= 0) & (page < num_pages)
        page_s = jnp.clip(page, 0, num_pages - 1)
        k_tile = pl.load(
            k_ref, (pl.dslice(page_s * page_size, page_size), slice(None))
        ).astype(jnp.float32)  # (page_size, d)
        v_tile = pl.load(
            v_ref, (pl.dslice(page_s * page_size, page_size), slice(None))
        ).astype(jnp.float32)
        if quantized:
            # int8 pages: dequantize per row inside the online-softmax
            # loop (scales are per (page-row, kv-head), written at
            # quantization time alongside the int8 rows).
            ks = pl.load(ks_ref, (pl.dslice(page_s * page_size, page_size),))
            vs = pl.load(vs_ref, (pl.dslice(page_s * page_size, page_size),))
            k_tile = k_tile * ks[:, None]
            v_tile = v_tile * vs[:, None]
        s = jnp.dot(k_tile, q)  # (page_size,)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = bi * page_size + jax.lax.iota(jnp.int32, page_size)
        mask = (kpos <= pos) & ok
        if window > 0:
            mask &= kpos > pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_cur)
        # mask p explicitly: when every position so far is masked, m_cur
        # is still NEG_INF and exp(s - m_cur) would be 1, not 0 — a
        # fully-masked query must come out all-zero
        p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)
        l_cur = l_prev * alpha + jnp.sum(p)
        acc = acc * alpha + jnp.dot(p, v_tile)
        return acc, m_cur, l_cur

    d = q_ref.shape[-1]
    acc0 = jnp.zeros((d,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(
        lo, hi, body, (acc0, jnp.float32(NEG_INF), jnp.float32(0.0))
    )
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_flash_attention(
    q: jnp.ndarray,  # (T, H, D) packed query tokens
    k_pool: jnp.ndarray,  # (num_pages, page_size, KV, D)
    v_pool: jnp.ndarray,  # (num_pages, page_size, KV, D)
    tables: jnp.ndarray,  # (num_slots, num_blocks) int32
    q_pos: jnp.ndarray,  # (T,) absolute position per query token
    q_slots: jnp.ndarray,  # (T,) cache slot per query token; < 0 = padding
    window: int = 0,
    softcap: float = 0.0,
    k_scale: jnp.ndarray = None,  # (num_pages, page_size, KV) f32, int8 pools
    v_scale: jnp.ndarray = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Flash attention over a paged KV pool (vLLM-style paged attention).

    The serving engine's token-packed decode/prefill step addresses KV by
    ``(slot, position)``; under the ``repro.serve.kv`` Paged layout the
    physical row is ``(tables[slot, position // page_size], position %
    page_size)``.  Instead of materializing each token's logical buffer
    (what the jnp path does), the kernel walks the query's *block table*:
    one program per (token, head) runs the online-softmax loop over that
    slot's admissible logical blocks only, loading each key tile by its
    page id — the loop never reads another slot's pages, so cross-request
    isolation is structural, not a mask (adversarially tested in
    ``tests/test_kernels.py``).  Entries with ``tables[s, b] >=
    num_pages`` (the unallocated sentinel) are mask-dropped; padding
    queries (``q_slots < 0``) return zero rows.

    Like the dense kernel above (whole-K block specs), each program
    *stages* the full per-head pool as one Pallas block and prunes reads
    inside it, which bounds the pool at VMEM size on real hardware
    (~16 MiB: fine for the serving shapes this repo compiles, not for a
    production multi-GiB pool).  Lifting that bound needs the
    scalar-prefetch grid spec (``pltpu.PrefetchScalarGridSpec``) DMA-ing
    pages by table entry — the known TPU follow-up.

    The jnp oracle is ``repro.kernels.ref.paged_attention_ref``; the
    fused XLA path used off-TPU is ``paged_attention_xla`` below.
    """
    t, h, d = q.shape
    num_pages, page_size, kvh, _ = k_pool.shape
    num_slots, num_blocks = tables.shape
    g = h // kvh
    quantized = k_scale is not None
    if quantized != (v_scale is not None):
        # raised, not assert-ed: a half-passed pair would silently attend
        # over raw int8 codes for one of K/V
        raise ValueError("pass both k_scale and v_scale, or neither")

    # (KV, num_pages * page_size, D): one flat row pool per KV head, so a
    # page id turns into a dslice start inside the kernel.
    kr = k_pool.transpose(2, 0, 1, 3).reshape(kvh, num_pages * page_size, d)
    vr = v_pool.transpose(2, 0, 1, 3).reshape(kvh, num_pages * page_size, d)

    kernel = functools.partial(
        _paged_attn_kernel,
        page_size=page_size, num_pages=num_pages, num_blocks=num_blocks,
        window=window, sm_scale=1.0 / math.sqrt(d), softcap=softcap,
        quantized=quantized,
    )
    in_specs = [
        pl.BlockSpec((None, None, d), lambda i, j: (i, j, 0)),  # q token/head
        pl.BlockSpec((None, num_pages * page_size, d), lambda i, j, g=g: (j // g, 0, 0)),
        pl.BlockSpec((None, num_pages * page_size, d), lambda i, j, g=g: (j // g, 0, 0)),
    ]
    operands = [q, kr, vr]
    if quantized:
        # Per-row dequant scales, flattened alongside their pools.
        ksr = k_scale.transpose(2, 0, 1).reshape(kvh, num_pages * page_size)
        vsr = v_scale.transpose(2, 0, 1).reshape(kvh, num_pages * page_size)
        in_specs.append(pl.BlockSpec((None, num_pages * page_size), lambda i, j, g=g: (j // g, 0)))
        in_specs.append(pl.BlockSpec((None, num_pages * page_size), lambda i, j, g=g: (j // g, 0)))
        operands.append(ksr.astype(jnp.float32))
        operands.append(vsr.astype(jnp.float32))
    in_specs += [
        pl.BlockSpec((num_slots, num_blocks), lambda i, j: (0, 0)),
        pl.BlockSpec((None,), lambda i, j: (i,)),
        pl.BlockSpec((None,), lambda i, j: (i,)),
    ]
    out = pl.pallas_call(
        kernel,
        grid=(t, h),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, d), q.dtype),
        interpret=interpret,
    )(
        *operands,
        tables.astype(jnp.int32),
        q_pos.astype(jnp.int32),
        q_slots.astype(jnp.int32),
    )
    return out


def paged_attention_xla(
    q: jnp.ndarray,  # (T, H, D) packed query tokens
    k_pool: jnp.ndarray,  # (num_pages, page_size, KV, D)
    v_pool: jnp.ndarray,  # (num_pages, page_size, KV, D)
    tables: jnp.ndarray,  # (num_slots, num_blocks) int32
    q_pos: jnp.ndarray,  # (T,) absolute positions
    q_slots: jnp.ndarray,  # (T,) slot per query; < 0 = padding
    window: int = 0,
    softcap: float = 0.0,
    k_scale: jnp.ndarray = None,
    v_scale: jnp.ndarray = None,
) -> jnp.ndarray:
    """Fused paged attention lowered through plain XLA (the non-TPU path).

    Same contract as the Pallas kernel: per-token block-table walk,
    unallocated-sentinel masking, zero rows for padding queries, optional
    per-row int8 dequant.  It gathers only each token's *own* pages (one
    (T, num_blocks) gather — never a whole-pool materialization), with
    unallocated blocks masked before the softmax, so the isolation
    guarantees match the kernel's.  On CPU this beats interpret-mode
    Pallas by >20x at serving shapes, which is why ``ops`` dispatches
    here off-TPU.
    """
    t, h, d = q.shape
    num_pages, page_size, kvh, _ = k_pool.shape
    nb = tables.shape[1]
    g = h // kvh
    valid_q = q_slots >= 0
    pages = tables[jnp.clip(q_slots, 0, tables.shape[0] - 1)]  # (T, NB)
    page_ok = (pages >= 0) & (pages < num_pages)  # sentinel AND negatives
    safe = jnp.where(page_ok, pages, 0)
    keys = k_pool[safe].astype(jnp.float32)  # (T, NB, ps, KV, D)
    vals = v_pool[safe].astype(jnp.float32)
    if (k_scale is not None) != (v_scale is not None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    if k_scale is not None:
        keys = keys * k_scale[safe][..., None]
        vals = vals * v_scale[safe][..., None]
    keys = keys.reshape(t, nb * page_size, kvh, d)
    vals = vals.reshape(t, nb * page_size, kvh, d)
    qg = q.reshape(t, kvh, g, d).astype(jnp.float32) / math.sqrt(d)
    logits = jnp.einsum("thgd,tkhd->thgk", qg, keys)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(nb * page_size)
    mask = (kpos[None, :] <= q_pos[:, None]) & valid_q[:, None]
    if window > 0:
        mask &= kpos[None, :] > q_pos[:, None] - window
    mask &= jnp.repeat(page_ok, page_size, axis=1)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    # re-mask after softmax: a fully-masked query (every page hostile or
    # unallocated) must output zeros, not a uniform mix of gathered rows
    w = jax.nn.softmax(logits, axis=-1) * mask[:, None, None, :]
    out = jnp.einsum("thgk,tkhd->thgd", w, vals)
    out = jnp.where(valid_q[:, None, None, None], out, 0.0)
    return out.reshape(t, h, d).astype(q.dtype)
