"""RMSNorm Pallas TPU kernel.

Row-tiled: each program instance normalizes a (BLOCK_ROWS, d) VMEM tile.
d stays whole inside the tile (the reduction axis must be local), so the
VMEM budget is BLOCK_ROWS * d * 4B for the fp32 math — BLOCK_ROWS=256 at
d=8192 is 8 MiB, within the ~16 MiB v5e VMEM with double-buffering
handled by the pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * s_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(
    x: jnp.ndarray,  # (..., d)
    scale: jnp.ndarray,  # (d,)
    eps: float = 1e-6,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of block_rows
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((rows + pad), d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
