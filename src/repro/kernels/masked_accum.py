"""Masked gradient accumulation Pallas TPU kernel (DropCompute hot loop).

Algorithm 1 line 7, fused:  acc <- acc + keep * scale * grad.

On TPU the accumulation buffers live in HBM in fp32 while micro-batch
gradients arrive in bf16; this kernel streams both through VMEM in
(BLOCK,) tiles, applies the keep-predicate as a scalar broadcast from
SMEM, and writes back in one pass — one HBM read of each operand and one
write, instead of the three passes (mask-mul, scale-mul, add) the naive
jnp composition would make if XLA failed to fuse across the pytree.

The predicate is a *scalar* per call (the whole micro-batch is kept or
dropped — exactly DropCompute's unit of work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 1024  # elements per tile: 256 KiB fp32 + 128 KiB bf16 in VMEM


def _accum_kernel(keep_ref, acc_ref, grad_ref, o_ref, *, scale):
    keep = keep_ref[0].astype(jnp.float32)
    acc = acc_ref[...]
    g = grad_ref[...].astype(jnp.float32)
    o_ref[...] = acc + keep * scale * g


def masked_accum(
    acc: jnp.ndarray,
    grad: jnp.ndarray,
    keep: jnp.ndarray,  # scalar (or 0-d) predicate
    scale: float = 1.0,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    assert acc.shape == grad.shape, (acc.shape, grad.shape)
    n = acc.size
    flat_acc = acc.reshape(n).astype(jnp.float32)
    flat_grad = grad.reshape(n)
    block = min(block, n)
    pad = (-n) % block
    if pad:
        flat_acc = jnp.pad(flat_acc, (0, pad))
        flat_grad = jnp.pad(flat_grad, (0, pad))
    keep_arr = jnp.reshape(keep, (1,)).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_accum_kernel, scale=scale),
        grid=((n + pad) // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.float32),
        interpret=interpret,
    )(keep_arr, flat_acc, flat_grad)
    if pad:
        out = out[:n]
    return out.reshape(acc.shape)


def masked_accum_tree(acc_tree, grad_tree, keep, scale: float = 1.0, interpret: bool = False):
    """Apply the fused accumulate across a gradient pytree."""
    return jax.tree.map(
        lambda a, g: masked_accum(a, g, keep, scale, interpret=interpret), acc_tree, grad_tree
    )
