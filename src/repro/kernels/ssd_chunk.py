"""SSD intra-chunk Pallas TPU kernel (Mamba-2's dominant compute).

Computes, for one (sequence-chunk, head) tile, the within-chunk term of
the state-space dual form:

    y[i] = sum_{j<=i} (C_i . B_j) * exp(cum[i] - cum[j]) * dt[j] * x[j]

i.e. masked decay-weighted attention with scores C B^T — two (L,L)xP
matmuls on the MXU plus VPU elementwise for the decay mask, exactly the
blocked structure `repro.models.ssm._ssd_chunked` evaluates in jnp (which
is the oracle, `ref.ssd_chunk_ref`).  The inter-chunk recurrence stays in
lax.scan (short serial dimension), matching the SSD paper's split.

Grid: (batch * n_chunks, heads).  VMEM per instance at L=256, N=128,
P=64 fp32: CB scores 256x256 + decay 256x256 + x/y 256x64 + B/C 256x128
~ 0.8 MiB — comfortably double-buffered.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (L, P)
    dt = dt_ref[...].astype(jnp.float32)  # (L,)
    cum = cum_ref[...].astype(jnp.float32)  # (L,) cumulative log-decay
    b = b_ref[...].astype(jnp.float32)  # (L, N)
    c = c_ref[...].astype(jnp.float32)  # (L, N)

    l = x.shape[0]
    scores = jnp.dot(c, b.T)  # (L, L): C_i . B_j
    diff = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (l, l), 1
    )
    decay = jnp.exp(-jnp.where(li, diff, 0.0)) * li
    att = scores * decay * dt[None, :]
    o_ref[...] = jnp.dot(att, x).astype(o_ref.dtype)


def _ssd_segment_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, seg_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (T, P)
    dt = dt_ref[...].astype(jnp.float32)  # (T,)
    cum = cum_ref[...].astype(jnp.float32)  # (T,)
    b = b_ref[...].astype(jnp.float32)  # (T, N)
    c = c_ref[...].astype(jnp.float32)  # (T, N)
    seg = seg_ref[...]  # (T,) int32

    t = x.shape[0]
    scores = jnp.dot(c, b.T)  # (T, T)
    diff = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (t, t), 1
    )
    li = li & (seg[:, None] == seg[None, :]) & (seg >= 0)[:, None]
    decay = jnp.exp(-jnp.where(li, diff, 0.0)) * li
    att = scores * decay * dt[None, :]
    o_ref[...] = jnp.dot(att, x).astype(o_ref.dtype)


def ssd_segment(
    x: jnp.ndarray,  # (T, H, P) packed tokens
    dt: jnp.ndarray,  # (T, H)
    cum: jnp.ndarray,  # (T, H) cumulative log-decay over the packed axis
    b: jnp.ndarray,  # (T, N)
    c: jnp.ndarray,  # (T, N)
    seg: jnp.ndarray,  # (T,) int32 segment (slot) ids; < 0 = padding
    interpret: bool = False,
) -> jnp.ndarray:
    """Segment-masked SSD term for token-packed layouts, (T, H, P).

    The packed analogue of the intra-chunk term: one (T, T) decay-weighted
    score matmul per head, with the causal mask intersected with a
    same-segment mask so flattened requests stay isolated (the same move
    ``flash_attention`` makes with q/kv_segment_ids).  Oracle:
    ``ref.ssd_segment_ref``.
    """
    t, h, p = x.shape
    n = b.shape[-1]
    br = jnp.broadcast_to(b[:, None, :], (t, h, n))
    cr = jnp.broadcast_to(c[:, None, :], (t, h, n))

    return pl.pallas_call(
        _ssd_segment_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((t, None, p), lambda j: (0, j, 0)),
            pl.BlockSpec((t, None), lambda j: (0, j)),
            pl.BlockSpec((t, None), lambda j: (0, j)),
            pl.BlockSpec((t, None, n), lambda j: (0, j, 0)),
            pl.BlockSpec((t, None, n), lambda j: (0, j, 0)),
            pl.BlockSpec((t,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((t, None, p), lambda j: (0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, p), x.dtype),
        interpret=interpret,
    )(x, dt, cum, br, cr, seg)


def ssd_chunk(
    x: jnp.ndarray,  # (B, NC, L, H, P)
    dt: jnp.ndarray,  # (B, NC, L, H)
    cum: jnp.ndarray,  # (B, NC, L, H) cumulative log-decay within chunk
    b: jnp.ndarray,  # (B, NC, L, N)
    c: jnp.ndarray,  # (B, NC, L, N)
    interpret: bool = False,
) -> jnp.ndarray:
    """Intra-chunk SSD term, (B, NC, L, H, P)."""
    bs, nc, l, h, p = x.shape
    n = b.shape[-1]
    g = bs * nc

    xr = x.reshape(g, l, h, p)
    dtr = dt.reshape(g, l, h)
    cumr = cum.reshape(g, l, h)
    br = jnp.broadcast_to(b.reshape(g, l, 1, n), (g, l, h, n))
    cr = jnp.broadcast_to(c.reshape(g, l, 1, n), (g, l, h, n))

    out = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(g, h),
        in_specs=[
            pl.BlockSpec((None, l, None, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, l, None), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, l, None), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, l, None, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, l, None, n), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, l, None, p), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((g, l, h, p), x.dtype),
        interpret=interpret,
    )(xr, dtr, cumr, br, cr)
    return out.reshape(bs, nc, l, h, p)
