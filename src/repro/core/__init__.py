"""DropCompute core: the paper's contribution as composable JAX modules."""
from .dropcompute import (
    DropConfig,
    accumulate_grads,
    completed_fraction,
    drop_mask,
    example_weights,
    weighted_loss,
)
from .engine import HostTimedEngine, InGraphEngine, make_grad_fn, simulated_latencies
from .simulate import PAPER_DELAY, LatencyModel, NoiseModel, SimResult, scale_curve, simulate
from .theory import (
    effective_speedup,
    expected_completed_microbatches,
    expected_max_normal,
    expected_step_time,
    norm_cdf,
    norm_ppf,
    optimal_tau,
    speedup_vs_workers,
)
from .threshold import ThresholdResult, gather_latency_profile, select_threshold

__all__ = [
    "DropConfig",
    "accumulate_grads",
    "completed_fraction",
    "drop_mask",
    "example_weights",
    "weighted_loss",
    "HostTimedEngine",
    "InGraphEngine",
    "make_grad_fn",
    "simulated_latencies",
    "PAPER_DELAY",
    "LatencyModel",
    "NoiseModel",
    "SimResult",
    "scale_curve",
    "simulate",
    "effective_speedup",
    "expected_completed_microbatches",
    "expected_max_normal",
    "expected_step_time",
    "norm_cdf",
    "norm_ppf",
    "optimal_tau",
    "speedup_vs_workers",
    "ThresholdResult",
    "gather_latency_profile",
    "select_threshold",
]
