"""DropCompute (Algorithm 1) as a composable JAX module.

The paper's mechanism: during gradient accumulation, each worker tracks the
wall-clock time of its local micro-batches and, once the cumulative compute
time crosses a threshold ``tau``, stops computing and joins the All-Reduce
with whatever gradients it has.  Synchronous semantics are preserved; only
the *batch size becomes stochastic*.

Two execution modes are provided (see ``repro.core.engine``):

* host-timed — faithful to the paper's user-level implementation: a Python
  loop around a jitted per-micro-batch gradient step, with a wall-clock
  check between accumulations;
* in-graph — the drop decision is computed inside the jitted step from a
  per-(worker, micro-batch) latency tensor (measured or sampled from
  ``repro.core.simulate``).  This is fully SPMD-compatible: the mask is a
  per-example weight and the cross-worker aggregation falls out of the
  global weighted-mean loss that pjit lowers to an All-Reduce.

This module holds the pure functions shared by both: drop masks,
normalization semantics, and the masked accumulation scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DropConfig:
    """Configuration for DropCompute.

    Attributes:
      enabled: master switch; disabled == vanilla synchronous accumulation.
      tau: compute threshold in seconds (set via Algorithm 2, see
        ``repro.core.threshold``). ``inf`` behaves exactly like vanilla.
      normalize: how the summed micro-batch gradients are normalized.
        * "nominal"  — divide by the *maximal* batch (paper's Algorithm 1:
          ``g_n += g^(m) / M``): dropped micro-batches shrink the gradient.
        * "computed" — divide by the number of actually-computed samples
          (the stochastic correction of appendix B.2.2); requires one extra
          scalar All-Reduce which rides along the gradient reduction.
      min_microbatches: never drop below this many accumulations per worker
        (guards against pathological thresholds; 1 keeps at least one).
    """

    enabled: bool = True
    tau: float = float("inf")
    normalize: str = "computed"
    min_microbatches: int = 1

    def __post_init__(self):
        if self.normalize not in ("nominal", "computed"):
            raise ValueError(f"bad normalize: {self.normalize}")


# ---------------------------------------------------------------------------
# Drop masks
# ---------------------------------------------------------------------------


def drop_mask(latencies: jnp.ndarray, tau, min_microbatches: int = 1) -> jnp.ndarray:
    """Compute the keep-mask from per-micro-batch latencies.

    Algorithm 1 line 8: worker n stops once its cumulative compute time
    exceeds tau, i.e. micro-batch m is *kept* iff  sum_{j<=m} t^(j) < tau.

    Args:
      latencies: (..., M) per-micro-batch compute times (seconds).
      tau: scalar threshold.
      min_microbatches: always keep at least this many leading micro-batches.

    Returns:
      float mask of the same shape: 1.0 = computed, 0.0 = dropped.
    """
    cum = jnp.cumsum(latencies, axis=-1)
    keep = cum < tau
    m = latencies.shape[-1]
    if min_microbatches > 0:
        idx = jnp.arange(m)
        keep = keep | (idx < min_microbatches)
    return keep.astype(jnp.float32)


def completed_fraction(mask: jnp.ndarray) -> jnp.ndarray:
    """M~ / M: average fraction of computed micro-batches (drop rate = 1-x)."""
    return jnp.mean(mask)


# ---------------------------------------------------------------------------
# Masked gradient accumulation
# ---------------------------------------------------------------------------


def accumulate_grads(
    grad_fn: Callable[[PyTree, Any], Tuple[PyTree, jnp.ndarray, jnp.ndarray]],
    params: PyTree,
    microbatches: PyTree,
    mask: jnp.ndarray,
    cfg: DropConfig,
) -> Tuple[PyTree, jnp.ndarray, dict]:
    """Scan over micro-batches, accumulating masked gradients (Algorithm 1).

    Args:
      grad_fn: (params, microbatch) -> (grads_sum, loss_sum, weight_sum)
        where grads/loss are *sums* over the micro-batch's examples/tokens
        and weight_sum is the number of tokens contributing.  Summing (not
        averaging) inside lets the normalization semantics live here.
      params: model parameters.
      microbatches: pytree whose leaves have leading dim M (micro-batch axis).
      mask: (M,) keep mask for the local worker (from ``drop_mask``).
      cfg: DropConfig.

    Returns:
      (grads, loss, stats) — grads normalized per ``cfg.normalize``; under
      pjit with the batch sharded over the data axis, the mean over workers
      of eq. (1) is realized by the compiler as an All-Reduce of these sums.
    """
    m = mask.shape[0]

    def body(carry, xs):
        g_acc, loss_acc, w_acc = carry
        mb, keep = xs

        def run(_):
            g, l, w = grad_fn(params, mb)
            return g, l, w

        def skip(_):
            return (
                jax.tree.map(jnp.zeros_like, g_acc),
                jnp.zeros_like(loss_acc),
                jnp.zeros_like(w_acc),
            )

        # lax.cond: dropped micro-batches cost ~0 compute in the lowered
        # program (both branches exist in HLO but only one executes).
        g, l, w = jax.lax.cond(keep > 0.5, run, skip, operand=None)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
        return (g_acc, loss_acc + l, w_acc + w), None

    g0 = jax.tree.map(jnp.zeros_like, params)
    (g_sum, loss_sum, w_sum), _ = jax.lax.scan(
        body, (g0, jnp.zeros(()), jnp.zeros(())), (microbatches, mask)
    )

    if cfg.normalize == "computed":
        denom = jnp.maximum(w_sum, 1.0)
    else:  # nominal: divide by the weight the full batch *would* have had.
        # Estimate the nominal per-microbatch weight from the computed ones;
        # exact when all micro-batches carry equal token counts.
        per_mb = w_sum / jnp.maximum(jnp.sum(mask), 1.0)
        denom = jnp.maximum(per_mb * m, 1.0)

    grads = jax.tree.map(lambda g: g / denom, g_sum)
    loss = loss_sum / jnp.maximum(w_sum, 1.0)
    stats = {
        "completed_microbatches": jnp.sum(mask),
        "completed_fraction": jnp.sum(mask) / m,
        "computed_weight": w_sum,
        "grad_denom": denom,
    }
    return grads, loss, stats


# ---------------------------------------------------------------------------
# Per-example weighting formulation (for single-pass global-batch steps)
# ---------------------------------------------------------------------------


def example_weights(
    mask: jnp.ndarray, batch_per_worker: int, microbatch_size: int
) -> jnp.ndarray:
    """Expand a (workers, M) keep-mask to per-example weights (workers*B,).

    Used by the SPMD dry-run/train step where the whole global batch is one
    tensor sharded over the data axis: example e of worker n belongs to
    micro-batch  floor(e / microbatch_size)  and inherits its mask.
    """
    w, m = mask.shape
    assert m * microbatch_size == batch_per_worker, (m, microbatch_size, batch_per_worker)
    per_ex = jnp.repeat(mask, microbatch_size, axis=1)  # (workers, B)
    return per_ex.reshape(w * batch_per_worker)


def weighted_loss(
    token_losses: jnp.ndarray,
    token_weights: jnp.ndarray,
    ex_weights: jnp.ndarray,
    cfg: DropConfig,
    nominal_weight: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Global weighted-mean loss implementing eq. (1) + drop normalization.

    Args:
      token_losses: (B, S) per-token CE.
      token_weights: (B, S) 1.0 for real tokens, 0.0 for padding.
      ex_weights: (B,) DropCompute keep weights from ``example_weights``.
      nominal_weight: scalar total token weight of the *undropped* batch
        (required for normalize="nominal").

    Returns (scalar loss, scalar computed-weight).
    """
    w = token_weights * ex_weights[:, None]
    num = jnp.sum(token_losses * w)
    computed = jnp.sum(w)
    if cfg.normalize == "computed":
        denom = jnp.maximum(computed, 1.0)
    else:
        if nominal_weight is None:
            nominal_weight = jnp.sum(token_weights)
        denom = jnp.maximum(nominal_weight, 1.0)
    return num / denom, computed
