"""Local-SGD + DropCompute (appendix B.3).

Local-SGD performs H local optimizer steps per worker between parameter
averaging rounds.  DropCompute integrates by treating *local steps* the way
Algorithm 1 treats gradient accumulations: when a worker's cumulative
compute time within a synchronization period crosses ``tau``, it skips its
remaining local steps and waits at the averaging barrier.

Two pieces:
  * a runtime model reproducing fig. 12 (straggling workers drawn per local
    step, uniform vs. single-server scenarios);
  * a functional trainer that runs N virtual workers (stacked params,
    vmapped local steps) so convergence with dropped local steps can be
    checked on a real (small) task.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Runtime model (fig. 12)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StragglerScenario:
    """Per-local-step straggler injection.

    mode="uniform": every (worker, step) is independently a straggler with
    probability p.  mode="single_server": only workers [0, server_size) can
    straggle (the realistic "one bad host" case).
    """

    mode: str = "uniform"
    p: float = 0.04
    delay: float = 1.0
    base: float = 0.1
    server_size: int = 8

    def sample(self, rng: np.random.Generator, iters: int, n: int, h: int):
        t = np.full((iters, n, h), self.base)
        hit = rng.random((iters, n, h)) < self.p
        if self.mode == "single_server":
            mask = np.zeros((1, n, 1), dtype=bool)
            mask[:, : self.server_size] = True
            hit = hit & mask
        return t + hit * self.delay


def localsgd_speedup(
    scenario: StragglerScenario,
    n_workers: int,
    sync_period: int,
    tau: float | None = None,
    iters: int = 500,
    tc: float = 0.05,
    seed: int = 0,
):
    """Relative speedup of (Local-SGD [+DropCompute]) vs fully synchronous.

    Synchronous baseline: barrier after every local step ->
        sum_h max_n t[:, n, h].
    Local-SGD: barrier only after H steps -> max_n sum_h t[:, n, h].
    +DropCompute: each worker caps its per-period compute at tau.

    Returns (speedup, dropped_fraction).
    """
    rng = np.random.default_rng(seed)
    t = scenario.sample(rng, iters, n_workers, sync_period)  # (I, N, H)

    sync = t.max(axis=1).sum(axis=-1) + sync_period * tc  # (I,)
    per_worker = t.sum(axis=-1)  # (I, N)

    if tau is None:
        local = per_worker.max(axis=1) + tc
        drop = 0.0
    else:
        cum = np.cumsum(t, axis=-1)
        done = cum < tau
        drop = 1.0 - done.mean()
        local = np.minimum(per_worker, tau).max(axis=1) + tc
    return float(sync.mean() / local.mean()), float(drop)


# ---------------------------------------------------------------------------
# Functional Local-SGD trainer (N virtual workers on one device)
# ---------------------------------------------------------------------------


def localsgd_train(
    loss_fn: Callable,
    params,
    data_fn: Callable[[int, int], tuple],  # (round, worker) -> microbatch seq
    n_workers: int,
    rounds: int,
    sync_period: int,
    lr: float,
    keep_mask: np.ndarray | None = None,
):
    """Run Local-SGD with optional per-(round, worker, step) keep mask.

    ``keep_mask[r, n, h] = 0`` means worker n skips local step h in round r
    (DropCompute drop).  Parameters are averaged across workers after each
    round.  Returns (params, losses per round).
    """
    stacked = jax.tree.map(lambda x: jnp.stack([x] * n_workers), params)
    grad = jax.grad(loss_fn)

    @jax.jit
    def local_round(ps, batches, keep):
        # ps: stacked params (N, ...); batches: (N, H, ...); keep: (N, H)
        def worker_steps(p, bs, ks):
            def body(p, xh):
                b, k = xh
                g = grad(p, b)
                p = jax.tree.map(lambda w, gg: w - lr * k * gg, p, g)
                return p, loss_fn(p, b)

            p, losses = jax.lax.scan(body, p, (bs, ks))
            return p, losses.mean()

        ps, losses = jax.vmap(worker_steps)(ps, batches, keep)
        avg = jax.tree.map(lambda x: jnp.mean(x, axis=0, keepdims=True), ps)
        ps = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_workers,) + a.shape[1:]), avg)
        return ps, losses.mean()

    losses = []
    for r in range(rounds):
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[data_fn(r, n) for n in range(n_workers)],
        )
        keep = (
            jnp.asarray(keep_mask[r], dtype=jnp.float32)
            if keep_mask is not None
            else jnp.ones((n_workers, sync_period))
        )
        stacked, l = local_round(stacked, batches, keep)
        losses.append(float(l))
    final = jax.tree.map(lambda x: x[0], stacked)
    return final, losses
