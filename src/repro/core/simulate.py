"""Latency models and cluster simulation for compute-variance studies.

Implements the simulated-delay environment of DropCompute (appendix B.1)
and the noise-distribution study of appendix C.3.

The paper models the per-micro-batch compute latency of worker ``n`` at
accumulation ``m`` as

    t_n^(m) = t_base + mu * eps,     eps = min(Z / alpha, beta)

with ``Z ~ LogNormal(4, 1)``, ``alpha = 2 exp(4.5)``, ``beta = 5.5`` so that
each accumulation takes x1.5 longer on average and at most x6.5 longer.

All samplers return a latency tensor of shape ``(iters, workers, M)``
(seconds).  Everything here is host-side numpy: these are *models* of
wall-clock behaviour used to drive simulations, analytics and the
in-graph DropCompute mask, never traced compute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Noise models (appendix B.1 and C.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """Additive noise ``eps`` on top of a deterministic micro-batch time.

    ``t = base * (1 + eps)`` where eps is drawn from ``kind``; matches the
    paper's ``t <- t + mu * eps`` with ``mu = base``.
    """

    kind: str = "lognormal"  # lognormal|normal|bernoulli|exponential|gamma|none
    # Parameters as used in appendix C.3, figures 13/14: mean/var of eps.
    mean: float = 0.5
    var: float = 0.25
    # Paper's B.1 parameterization (overrides mean/var when kind=paper_lognormal)
    ln_mu: float = 4.0
    ln_sigma: float = 1.0
    alpha: float = 2.0 * math.exp(4.5)
    beta: float = 5.5

    def sample(self, rng: np.random.Generator, shape) -> np.ndarray:
        k = self.kind
        if k == "none":
            return np.zeros(shape)
        if k == "paper_lognormal":
            z = rng.lognormal(self.ln_mu, self.ln_sigma, size=shape)
            return np.minimum(z / self.alpha, self.beta)
        if k == "lognormal":
            # Solve LN(mu, sig) with given mean/var:
            #   mean = exp(mu + sig^2/2); var = (exp(sig^2)-1) exp(2mu+sig^2)
            sig2 = math.log(1.0 + self.var / self.mean**2)
            mu = math.log(self.mean) - sig2 / 2.0
            return rng.lognormal(mu, math.sqrt(sig2), size=shape)
        if k == "normal":
            return np.maximum(
                rng.normal(self.mean, math.sqrt(self.var), size=shape), 0.0
            )
        if k == "bernoulli":
            # eps = c * Br(p); mean = c p, var = c^2 p (1-p)
            # With p=0.5: c = 2*mean, var = mean^2 -> matches table (0.45 Br(.5)).
            p = 1.0 / (1.0 + self.var / self.mean**2)
            c = self.mean / p
            return c * (rng.random(size=shape) < p)
        if k == "exponential":
            return rng.exponential(self.mean, size=shape)
        if k == "gamma":
            # alpha = mean^2/var, beta(rate) = mean/var
            a = self.mean**2 / self.var
            scale = self.var / self.mean
            return rng.gamma(a, scale, size=shape)
        raise ValueError(f"unknown noise kind: {k}")


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Per-micro-batch latency ``t = base * (1 + eps)`` (seconds)."""

    base: float = 0.45  # figure 13/14 use 0.45 s per accumulation
    noise: NoiseModel = dataclasses.field(default_factory=NoiseModel)
    # Optional per-worker speed skew (heterogeneous clusters): worker n runs
    # at base * (1 + skew * n / N).
    worker_skew: float = 0.0
    # Straggler injection: with prob p a worker's whole step gains `delay` s.
    straggler_prob: float = 0.0
    straggler_delay: float = 1.0

    def sample(
        self, rng: np.random.Generator, iters: int, workers: int, m: int
    ) -> np.ndarray:
        eps = self.noise.sample(rng, (iters, workers, m))
        base = np.full((1, workers, 1), self.base)
        if self.worker_skew:
            base = base * (
                1.0 + self.worker_skew * np.arange(workers)[None, :, None] / workers
            )
        t = base * (1.0 + eps)
        if self.straggler_prob > 0:
            hit = rng.random((iters, workers, 1)) < self.straggler_prob
            t = t + hit * (self.straggler_delay / m)
        return t

    def sample_at(
        self, step: int, workers: int, m: int, seed: Optional[int] = 0
    ) -> np.ndarray:
        """One step's (N, M) draw keyed by ``(seed, step)`` — the same
        distribution as ``sample`` but deterministic per step regardless of
        call order, so a checkpointed run resumes onto the identical
        latency stream.  Fault scenarios (``train.resilience.faults``)
        override this with their perturbation stack."""
        rng = np.random.default_rng([0 if seed is None else seed, step])
        return self.sample(rng, 1, workers, m)[0]

    @property
    def mean(self) -> float:
        return self.base * (1.0 + self.noise_mean)

    @property
    def noise_mean(self) -> float:
        n = self.noise
        if n.kind == "none":
            return 0.0
        if n.kind == "paper_lognormal":
            # E[min(Z/a, b)] estimated numerically once (stable, cached).
            rng = np.random.default_rng(0)
            return float(np.mean(n.sample(rng, 200_000)))
        return n.mean

    @property
    def std(self) -> float:
        n = self.noise
        if n.kind == "none":
            return 0.0
        if n.kind == "paper_lognormal":
            rng = np.random.default_rng(0)
            return float(self.base * np.std(n.sample(rng, 200_000)))
        return self.base * math.sqrt(n.var)


PAPER_DELAY = LatencyModel(base=0.45, noise=NoiseModel(kind="paper_lognormal"))

# ---------------------------------------------------------------------------
# Cluster simulation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    """Outcome of simulating synchronous training with/without DropCompute."""

    t: np.ndarray  # (I, N, M) micro-batch latencies
    T_n: np.ndarray  # (I, N) per-worker step compute time
    T: np.ndarray  # (I,) iteration compute time = max_n T_n
    tc: float  # serial/communication latency per iteration

    @property
    def mean_iter_time(self) -> float:
        return float(np.mean(self.T) + self.tc)

    @property
    def mean_worker_time(self) -> float:
        return float(np.mean(self.T_n) + self.tc)

    def with_threshold(self, tau: float, min_microbatches: int = 1):
        """Apply DropCompute with threshold ``tau`` (on compute time only).

        Mirrors ``dropcompute.drop_mask`` exactly: micro-batch ``m`` is kept
        iff its cumulative time is below ``tau`` OR ``m < min_microbatches``
        (a worker never drops its first ``min_microbatches`` accumulations,
        so tiny thresholds report >= min_microbatches/M completion, not 0).
        The iteration time is floored accordingly: when the guaranteed
        micro-batches overrun ``tau``, the step takes as long as the slowest
        worker needs to compute them.

        Returns (iteration_time (I,), completed micro-batch fraction (I,)).
        """
        cum = np.cumsum(self.t, axis=-1)  # (I, N, M)
        done = cum < tau
        if min_microbatches > 0:
            done |= np.arange(self.t.shape[-1]) < min_microbatches
        counts = done.sum(axis=-1)  # (I, N) kept micro-batches
        m_tilde = counts.mean(axis=-1)  # (I,) avg over workers
        # worker time = cum at its last kept micro-batch (prefix mask)
        w_time = np.take_along_axis(
            cum, np.maximum(counts - 1, 0)[..., None], axis=-1
        )[..., 0]
        forced = np.where(counts > 0, w_time, 0.0).max(axis=-1)  # (I,)
        t_iter = np.maximum(np.minimum(self.T, tau), forced) + self.tc
        return t_iter, m_tilde / self.t.shape[-1]

    def effective_speedup(self, tau: float, min_microbatches: int = 1) -> float:
        """Empirical S_eff(tau), eq. (6), averaged per-iteration (Alg. 2)."""
        t_iter, frac = self.with_threshold(tau, min_microbatches)
        s_i = (self.T + self.tc) / t_iter * frac
        return float(np.mean(s_i))


def simulate(
    model: LatencyModel,
    iters: int,
    workers: int,
    m: int,
    tc: float = 0.5,
    seed: int = 0,
) -> SimResult:
    rng = np.random.default_rng(seed)
    t = model.sample(rng, iters, workers, m)
    t_n = t.sum(axis=-1)
    return SimResult(t=t, T_n=t_n, T=t_n.max(axis=-1), tc=tc)


def scale_curve(
    model: LatencyModel,
    worker_counts,
    m: int,
    tc: float = 0.5,
    iters: int = 200,
    tau: Optional[float] = None,
    seed: int = 0,
):
    """Throughput-per-worker scale graph (figure 1).

    Returns dict: N -> (throughput in micro-batches/s, scaling efficiency
    vs. single worker).
    """
    out = {}
    single = simulate(model, iters, 1, m, tc, seed)
    t1 = single.mean_iter_time
    for n in worker_counts:
        sim = simulate(model, iters, n, m, tc, seed + n)
        if tau is None:
            t_iter = sim.mean_iter_time
            mbs = n * m / t_iter
        else:
            t_it, frac = sim.with_threshold(tau)
            mbs = float(np.mean(n * m * frac / t_it))
        out[n] = (mbs, mbs / (n * m / t1))
    return out
