"""Closed-form runtime analysis of synchronous training and DropCompute.

Implements the analytical results of section 4 and appendix C.2:

* eq. (3):  pdf of the max of N i.i.d. worker step times,
* eq. (4)/(7):  Bailey et al. approximation of E[max of N normals],
* eq. (5)/(10): expected completed micro-batches E[M~(tau)],
* eq. (6)/(11): expected effective speedup E[S_eff(tau)],
* the asymptotic E[T] = Theta(sqrt(log N)) behaviour,
* the optimal-threshold rule tau* = argmax E[S_eff(tau)].

Everything is pure numpy (host-side analytics).
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

_EULER_GAMMA = 0.5772156649015329


def _ndtri(p):
    """Inverse standard-normal CDF (Acklam's rational approximation).

    Max abs error ~1.15e-9, plenty for the runtime analytics here (scipy is
    not available in this environment).
    """
    p = np.asarray(p, dtype=np.float64)
    a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
    b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00]
    plow, phigh = 0.02425, 1 - 0.02425
    out = np.empty_like(p)

    lo = p < plow
    hi = p > phigh
    mid = ~(lo | hi)

    if np.any(mid):
        q = p[mid] - 0.5
        r = q * q
        num = ((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]
        den = (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
        out[mid] = num * q / den
    if np.any(lo):
        q = np.sqrt(-2 * np.log(p[lo]))
        num = ((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]
        den = ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
        out[lo] = num / den
    if np.any(hi):
        q = np.sqrt(-2 * np.log(1 - p[hi]))
        num = ((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]
        den = ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
        out[hi] = -num / den
    return out


def norm_cdf(x):
    x = np.asarray(x, dtype=np.float64)
    return 0.5 * (1.0 + np.vectorize(math.erf)(x / math.sqrt(2.0)))


def norm_ppf(p):
    return _ndtri(p)


# ---------------------------------------------------------------------------
# eq. (3): distribution of the max
# ---------------------------------------------------------------------------


def max_pdf_iid(x, pdf, cdf, n: int):
    """f_T(x) = N f(x) F(x)^{N-1} for i.i.d. worker step times."""
    return n * pdf(x) * np.power(np.clip(cdf(x), 0.0, 1.0), n - 1)


# ---------------------------------------------------------------------------
# eq. (4)/(7): expected max of N normals (Bailey et al. 2014)
# ---------------------------------------------------------------------------


def expected_max_normal(mu: float, sigma: float, n: int) -> float:
    """E[max of N iid N(mu, sigma^2)] via the Bailey approximation (eq. 4)."""
    if n <= 1:
        return mu
    g = _EULER_GAMMA
    q1 = float(norm_ppf(1.0 - 1.0 / n))
    q2 = float(norm_ppf(1.0 - 1.0 / (math.e * n)))
    return sigma * ((1.0 - g) * q1 + g * q2) + mu


def expected_step_time(
    mu: float, sigma: float, m: int, n: int, tc: float = 0.0
) -> float:
    """eq. (7): E[T] for N workers each running M accumulations ~ N(mu, s^2).

    Under CLT, T_n ~ N(M mu, M sigma^2); add the serial latency tc.
    """
    return expected_max_normal(m * mu, math.sqrt(m) * sigma, n) + tc


def asymptotic_max_coefficient(n: int) -> float:
    """The Theta(sqrt(log N)) asymptote: Phi^-1(1-y) ~ sqrt(-2 log y)."""
    return math.sqrt(2.0 * math.log(max(n, 2)))


# ---------------------------------------------------------------------------
# eq. (5)/(10): expected completed micro-batches
# ---------------------------------------------------------------------------


def expected_completed_microbatches(
    tau: float, mu: float, sigma: float, m: int
) -> float:
    """E[M~(tau)] = sum_m Phi((tau - m mu) / sqrt(m sigma^2))  (eq. 5)."""
    ms = np.arange(1, m + 1, dtype=np.float64)
    z = (tau - ms * mu) / np.sqrt(ms * sigma**2 + 1e-30)
    return float(np.sum(norm_cdf(z)))


# ---------------------------------------------------------------------------
# eq. (6)/(11): effective speedup
# ---------------------------------------------------------------------------


def effective_speedup(
    tau: float,
    mu: float,
    sigma: float,
    m: int,
    n: int,
    tc: float = 0.0,
    e_t: Optional[float] = None,
) -> float:
    """Analytic E[S_eff(tau)] per eq. (11).

    ``e_t`` lets callers plug the *empirical* E[T] (compute part only, without
    tc) when the Gaussian approximation of the max is poor (fig. 3b).
    """
    if e_t is None:
        e_t = expected_max_normal(m * mu, math.sqrt(m) * sigma, n)
    m_tilde = expected_completed_microbatches(tau, mu, sigma, m)
    return (m_tilde / m) * (e_t + tc) / (min(tau, e_t) + tc)


def optimal_tau(
    mu: float,
    sigma: float,
    m: int,
    n: int,
    tc: float = 0.0,
    e_t: Optional[float] = None,
    grid: Optional[np.ndarray] = None,
):
    """tau* = argmax_tau E[S_eff(tau)] over a grid (section 4.4 / C.2).

    Returns (tau*, S_eff(tau*)).
    """
    if grid is None:
        lo = max(0.55 * m * mu, mu)  # assumption C.3: tau > M mu / 2
        hi = m * (mu + 4.0 * sigma)
        grid = np.linspace(lo, hi, 512)
    vals = np.array([effective_speedup(t, mu, sigma, m, n, tc, e_t) for t in grid])
    i = int(np.argmax(vals))
    return float(grid[i]), float(vals[i])


def speedup_vs_workers(
    mu: float, sigma: float, m: int, ns, tc: float = 0.0
) -> dict:
    """E[S_eff(tau*)] as a function of N — shows S_eff -> inf as N grows."""
    out = {}
    for n in ns:
        tau, s = optimal_tau(mu, sigma, m, n, tc)
        out[int(n)] = {"tau": tau, "speedup": s}
    return out
