"""Automatic threshold selection (Algorithm 2, appendix C.1).

Each worker records the wall-clock latency of every micro-batch for the
first ``I`` iterations plus the per-iteration communication time ``T_i^c``.
The samples are synchronized across workers (an all-gather that happens
once per training session) and every worker then runs the same
deterministic grid search below, so all workers independently arrive at
the same ``tau*`` — no coordinator required (decentralized, like the
All-Reduce itself).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class ThresholdResult:
    tau: float
    speedup: float
    grid: np.ndarray
    speedups: np.ndarray
    completion: np.ndarray  # mean fraction of computed micro-batches per tau
    step_speedup: np.ndarray  # time-only speedup per tau (fig. 3c)

    def summary(self) -> str:
        return (
            f"tau*={self.tau:.4f}s  S_eff={self.speedup:.4f}  "
            f"completion={self.completion[np.argmax(self.speedups)]:.3f}"
        )


def fill_profile_nans(latencies: np.ndarray) -> np.ndarray:
    """Fill NaN micro-batch times with their column mean (over I and N).

    ``HostTimedEngine.profile()`` NaN-pads micro-batches a worker dropped;
    Algorithm 2 wants a dense profile, and the best unbiased stand-in for
    a never-run accumulation is the mean time of that accumulation slot
    where it *was* run.  Columns with no observations fall back to the
    global mean.  No-op (same array returned) when nothing is NaN.
    """
    lat = np.asarray(latencies, dtype=np.float64)
    if not np.isnan(lat).any():
        return lat
    col = np.nanmean(lat, axis=(0, 1), keepdims=True) if lat.ndim == 3 else np.nanmean(lat)
    col = np.where(np.isnan(col), np.nanmean(lat), col)
    return np.where(np.isnan(lat), col, lat)


def select_threshold(
    latencies: np.ndarray,
    tc,
    grid: Optional[Sequence[float]] = None,
    grid_size: int = 256,
    min_microbatches: int = 1,
    max_drop: Optional[float] = None,
) -> ThresholdResult:
    """Algorithm 2: pick tau* maximizing the mean per-iteration S_eff.

    Args:
      latencies: (I, N, M) micro-batch times t_{i,n}^{(m)} gathered from all
        N workers over I profiling iterations.  NaNs (host-timed profiles of
        partially-dropped steps) are filled via :func:`fill_profile_nans`.
      tc: scalar or (I,) per-iteration communication/serial time.
      grid: candidate thresholds; default = linspace over observed range.
      max_drop: optional drop-rate ceiling — tau* is restricted to grid
        points whose mean completion is >= 1 - max_drop (the online
        controller's guardrail).  If no grid point qualifies, the
        highest-completion point wins.

    Returns ThresholdResult with tau* = argmax_tau mean_i S_i(tau).
    """
    lat = np.asarray(latencies, dtype=np.float64)
    if lat.ndim != 3:
        raise ValueError(f"latencies must be (I, N, M), got {lat.shape}")
    lat = fill_profile_nans(lat)
    i_, n_, m_ = lat.shape
    tc = np.broadcast_to(np.asarray(tc, dtype=np.float64), (i_,))

    cum = np.cumsum(lat, axis=-1)  # (I, N, M): T_{i,n}^{(m)}
    t_in = cum[..., -1]  # (I, N): worker step compute time
    t_i = t_in.max(axis=1)  # (I,): slowest worker

    if grid is None:
        lo = float(np.quantile(t_in, 0.05))
        hi = float(t_i.max()) * 1.05
        grid = np.linspace(lo, hi, grid_size)
    grid = np.asarray(list(grid), dtype=np.float64)

    # completed micro-batches per (tau, I): mean_n sum_m [T_{i,n}^{(m)} < tau],
    # with the same min_microbatches floor as dropcompute.drop_mask /
    # SimResult.with_threshold (workers never drop their first few).
    done = cum[None, ...] < grid[:, None, None, None]  # (G, I, N, M)
    if min_microbatches > 0:
        done |= np.arange(m_) < min_microbatches
    m_tilde = done.sum(axis=-1).mean(axis=-1)  # (G, I)

    # worker time = cum at its last kept micro-batch; done is a prefix
    # mask, so gather at index count-1 instead of materializing a
    # (G, I, N, M) float temp alongside the boolean one.
    counts = done.sum(axis=-1)  # (G, I, N)
    w_time = np.take_along_axis(
        np.broadcast_to(cum[None], done.shape),  # view, no copy
        np.maximum(counts - 1, 0)[..., None], axis=-1,
    )[..., 0]
    w_time = np.where(counts > 0, w_time, 0.0)  # (G, I, N)
    forced = w_time.max(axis=-1)  # (G, I)
    t_drop = (
        np.maximum(np.minimum(t_i[None, :], grid[:, None]), forced) + tc[None, :]
    )  # (G, I)
    s_step = (t_i + tc)[None, :] / t_drop  # time-only speedup
    s_i = s_step * (m_tilde / m_)  # effective speedup per iteration
    s_eff = s_i.mean(axis=1)  # (G,)

    completion = (m_tilde / m_).mean(axis=1)  # (G,)
    if max_drop is not None:
        allowed = completion >= 1.0 - max_drop
        if not allowed.any():
            allowed = completion >= completion.max()
        k = int(np.argmax(np.where(allowed, s_eff, -np.inf)))
    else:
        k = int(np.argmax(s_eff))
    return ThresholdResult(
        tau=float(grid[k]),
        speedup=float(s_eff[k]),
        grid=grid,
        speedups=s_eff,
        completion=completion,
        step_speedup=s_step.mean(axis=1),
    )


def gather_latency_profile(local_latencies: np.ndarray, axis_name=None):
    """All-gather per-worker latency profiles.

    In a real multi-host deployment this is a
    ``jax.experimental.multihost_utils.process_allgather``; in this
    single-process environment the "workers" are the data-parallel shards
    and the profile is already globally replicated, so this is an identity
    with shape validation.  Kept as a seam so the launcher can swap in the
    real collective.
    """
    lat = np.asarray(local_latencies)
    if lat.ndim == 2:  # (I, M) single worker -> (I, 1, M)
        lat = lat[:, None, :]
    return lat
