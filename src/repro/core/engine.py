"""Gradient-accumulation engines implementing Algorithm 1.

Two interchangeable engines produce ``(grads, loss, stats)`` for one
optimizer step:

* ``HostTimedEngine`` — the paper's user-level implementation, faithfully:
  a Python loop over a jitted per-micro-batch gradient step with a
  wall-clock check between accumulations (the "do (1) and (2) in parallel"
  of Algorithm 1 degenerates to a timeout check between accumulations,
  exactly like the paper's reference implementation; see its §6
  Limitations).  Used for real training runs where compute variance is
  physical.

* ``InGraphEngine`` — a single jitted step that scans over micro-batches
  and masks them from a latency tensor (measured previously or sampled
  from a ``LatencyModel``).  Deterministic and SPMD-friendly; used for the
  reproducible experiments, the benchmarks and the multi-pod dry-run.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dropcompute import DropConfig, accumulate_grads, drop_mask
from .simulate import LatencyModel

PyTree = Any

# grad_fn(params, microbatch) -> (grads_sum, loss_sum, weight_sum)
GradFn = Callable[[PyTree, Any], Tuple[PyTree, jnp.ndarray, jnp.ndarray]]


def make_grad_fn(loss_fn: Callable[[PyTree, Any], Tuple[jnp.ndarray, jnp.ndarray]]) -> GradFn:
    """Lift loss_fn(params, mb) -> (loss_sum, weight_sum) into a GradFn."""

    def summed(params, mb):
        loss_sum, w = loss_fn(params, mb)
        return loss_sum, w

    def grad_fn(params, mb):
        (loss_sum, w), grads = jax.value_and_grad(summed, has_aux=True)(params, mb)
        return grads, loss_sum, w

    return grad_fn


class HostTimedEngine:
    """Algorithm 1 with real wall-clock timing (decentralized).

    Every call to ``step`` runs micro-batches until either all M are done or
    the measured compute time exceeds ``cfg.tau``.  Latency samples are
    recorded so a profiling phase can feed Algorithm 2.
    """

    def __init__(self, grad_fn: GradFn, cfg: DropConfig):
        self.cfg = cfg
        self._grad_fn = jax.jit(grad_fn)
        self._acc = jax.jit(
            lambda a, g, l, w, ls, ws: (
                jax.tree.map(jnp.add, a, g),
                ls + l,
                ws + w,
            )
        )
        self.latency_log: list[list[float]] = []

    def step(self, params: PyTree, microbatches: PyTree) -> Tuple[PyTree, jnp.ndarray, dict]:
        m = jax.tree.leaves(microbatches)[0].shape[0]
        g_sum = None
        loss_sum = jnp.zeros(())
        w_sum = jnp.zeros(())
        lat: list[float] = []
        computed = 0
        t0 = time.perf_counter()
        for i in range(m):
            if (
                self.cfg.enabled
                and computed >= self.cfg.min_microbatches
                and (time.perf_counter() - t0) > self.cfg.tau
            ):
                break  # drop remaining compute, go to All-Reduce
            mb = jax.tree.map(lambda x: x[i], microbatches)
            tm0 = time.perf_counter()
            g, l, w = self._grad_fn(params, mb)
            jax.block_until_ready(l)
            lat.append(time.perf_counter() - tm0)
            if g_sum is None:
                g_sum, loss_sum, w_sum = g, l, w
            else:
                g_sum, loss_sum, w_sum = self._acc(g_sum, g, l, w, loss_sum, w_sum)
            computed += 1
        self.latency_log.append(lat)

        if self.cfg.normalize == "computed":
            denom = jnp.maximum(w_sum, 1.0)
        else:
            denom = jnp.maximum(w_sum / max(computed, 1) * m, 1.0)
        grads = jax.tree.map(lambda g: g / denom, g_sum)
        stats = {
            "completed_microbatches": float(computed),
            "completed_fraction": computed / m,
            "computed_weight": w_sum,
        }
        return grads, loss_sum / jnp.maximum(w_sum, 1.0), stats

    def profile(self) -> np.ndarray:
        """(I, 1, M) latency tensor for Algorithm 2 (ragged rows padded)."""
        if not self.latency_log:
            return np.zeros((0, 1, 0))
        m = max(len(r) for r in self.latency_log)
        out = np.full((len(self.latency_log), m), np.nan)
        for i, r in enumerate(self.latency_log):
            out[i, : len(r)] = r
        return out[:, None, :]


class InGraphEngine:
    """Algorithm 1 with the drop decision inside the jitted step.

    The latency tensor (M,) or (workers, M) is an *input*; pair with
    ``LatencyModel.sample`` for simulation or with measured host timings.
    """

    def __init__(self, grad_fn: GradFn, cfg: DropConfig):
        self.cfg = cfg
        self._step = jax.jit(functools.partial(self._step_impl, grad_fn, cfg))

    @staticmethod
    def _step_impl(grad_fn, cfg, params, microbatches, latencies):
        mask = drop_mask(latencies, cfg.tau, cfg.min_microbatches)
        if not cfg.enabled:
            mask = jnp.ones_like(mask)
        return accumulate_grads(grad_fn, params, microbatches, mask, cfg)

    def step(self, params, microbatches, latencies):
        return self._step(params, microbatches, jnp.asarray(latencies))


def simulated_latencies(
    model: LatencyModel, steps: int, workers: int, m: int, seed: int = 0
) -> np.ndarray:
    """(steps, workers, M) host-side latency draws for InGraphEngine."""
    rng = np.random.default_rng(seed)
    return model.sample(rng, steps, workers, m)
