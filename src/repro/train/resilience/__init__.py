"""``repro.train.resilience`` — tail-tolerant training as a subsystem.

Three pillars, wired through ``repro.train.trainer``:

* :mod:`telemetry` — per-worker, per-step compute-time collection in
  bounded ring buffers with streaming mean/std/percentile estimators;
  the rolling window feeds Algorithm 2 online.
* :mod:`controller` — the online tau controller: re-estimates tau* from
  the telemetry window during the run, with hysteresis, a
  recompile-cost amortization gate (tau is baked into the traced SPMD
  drop mask, so changing it costs a rebuild) and drop-rate guardrails.
* :mod:`faults` — seeded straggler/fault injection (log-normal and
  Pareto tails, persistent slow ranks, transient stalls, base-rate
  ramps), composable with ``core.simulate.LatencyModel`` and usable as
  real injected delays in SPMD runs.
"""
from .controller import ControllerConfig, Decision, TauController, effective_speedup_at
from .faults import (
    SCENARIOS,
    BadNode,
    FaultyLatencyModel,
    LogNormalTail,
    ParetoTail,
    RampSlowdown,
    TransientStall,
    make_scenario,
)
from .telemetry import (
    ComputeTelemetry,
    P2Quantile,
    RingBuffer,
    StepRecord,
    StreamingMoments,
)

__all__ = [
    "ControllerConfig",
    "Decision",
    "TauController",
    "effective_speedup_at",
    "SCENARIOS",
    "BadNode",
    "FaultyLatencyModel",
    "LogNormalTail",
    "ParetoTail",
    "RampSlowdown",
    "TransientStall",
    "make_scenario",
    "ComputeTelemetry",
    "P2Quantile",
    "RingBuffer",
    "StepRecord",
    "StreamingMoments",
]
