"""Seeded straggler/fault injection for compute-time variance studies.

Perturbs the per-worker (N, M) micro-batch latency tensor a trainer step
draws from its ``LatencyModel`` with the heavy-tail regimes that motivate
DropCompute (and OptiReduce's tail analysis): log-normal and Pareto
per-micro-batch tails, a persistent slow rank ("bad node"), transient
whole-step stalls, and base-rate ramps (non-stationary clusters — the
regime where a one-shot-calibrated tau goes stale).

Everything is deterministic in ``(seed, step)``: fault randomness is keyed
by ``default_rng([seed, step, fault_index])``, never by call order, so a
resumed run replays exactly the same perturbations and two policies under
the same scenario see identical latency tensors.

``FaultyLatencyModel`` is drop-in wherever a ``LatencyModel`` is accepted
(``sample`` has the same signature); the trainer prefers ``sample_at`` so
per-step determinism survives checkpoint/restore.  For *real* SPMD runs,
``host_delay_at`` returns the injected extra seconds for one rank so a
launcher can ``time.sleep`` them around its jitted step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ...core.simulate import LatencyModel, NoiseModel


class Fault:
    """Base class: a deterministic perturbation of one step's latencies."""

    def perturb(self, t: np.ndarray, step: int, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ParetoTail(Fault):
    """Pareto(alpha) multiplicative tail on random micro-batches.

    With probability ``prob`` per (worker, micro-batch), the latency gains
    ``scale * X`` seconds, ``X ~ Pareto(alpha)`` — alpha <= 2 gives the
    infinite-variance tails where the max over workers diverges fastest.
    """

    alpha: float = 1.8
    scale: float = 0.3
    prob: float = 0.15

    def perturb(self, t, step, rng):
        hit = rng.random(t.shape) < self.prob
        tail = self.scale * rng.pareto(self.alpha, size=t.shape)
        return t + hit * tail


@dataclasses.dataclass(frozen=True)
class LogNormalTail(Fault):
    """Additive log-normal tail (the paper's B.1 shape, heavier knobs)."""

    mu: float = -1.0
    sigma: float = 1.2
    prob: float = 0.2

    def perturb(self, t, step, rng):
        hit = rng.random(t.shape) < self.prob
        return t + hit * rng.lognormal(self.mu, self.sigma, size=t.shape)


@dataclasses.dataclass(frozen=True)
class BadNode(Fault):
    """A persistent slow rank: worker ``rank`` runs ``factor`` x slower
    for steps in ``[start, end)`` (``end=None`` = forever).  ``rank=-1``
    picks a worker deterministically from the scenario seed."""

    rank: int = -1
    factor: float = 2.0
    start: int = 0
    end: Optional[int] = None

    def perturb(self, t, step, rng):
        if step < self.start or (self.end is not None and step >= self.end):
            return t
        n = t.shape[0]
        # seeded, step-independent choice: key the pick by start, not step
        rank = self.rank if self.rank >= 0 else int(
            np.random.default_rng([17, self.start]).integers(0, n)
        )
        out = t.copy()
        out[rank % n] = out[rank % n] * self.factor
        return out


@dataclasses.dataclass(frozen=True)
class TransientStall(Fault):
    """With probability ``prob`` per step, one random worker stalls for
    ``stall_s`` seconds before its first micro-batch (GC pause, network
    hiccup, preemption)."""

    prob: float = 0.05
    stall_s: float = 3.0

    def perturb(self, t, step, rng):
        if rng.random() >= self.prob:
            return t
        out = t.copy()
        w = int(rng.integers(0, t.shape[0]))
        out[w, 0] = out[w, 0] + self.stall_s
        return out


@dataclasses.dataclass(frozen=True)
class RampSlowdown(Fault):
    """All workers slow by ``factor`` from step ``start`` on — the
    non-stationary base shift that makes a statically calibrated tau
    stale (too low for the new regime)."""

    start: int = 0
    factor: float = 1.5

    def perturb(self, t, step, rng):
        return t * self.factor if step >= self.start else t


@dataclasses.dataclass(frozen=True)
class FaultyLatencyModel:
    """A ``LatencyModel`` composed with a fault stack.

    ``sample_at(step, N, M)`` is the trainer's entry point: base draw and
    every fault keyed by ``(seed, step)``.  ``sample(rng, I, N, M)`` keeps
    the plain ``LatencyModel`` signature for ``core.simulate.simulate``
    and friends (iterations are treated as steps ``0..I-1``).
    """

    base: LatencyModel = dataclasses.field(default_factory=LatencyModel)
    faults: Tuple[Fault, ...] = ()
    seed: int = 0

    def sample_at(
        self, step: int, workers: int, m: int, seed: Optional[int] = None
    ) -> np.ndarray:
        """(N, M) draw keyed by ``(seed, step)``; ``seed=None`` uses the
        scenario's own seed (the trainer passes its run seed so two
        policies under one scenario replay identical latencies)."""
        key = self.seed if seed is None else int(seed)
        rng = np.random.default_rng([key, step])
        t = self.base.sample(rng, 1, workers, m)[0]
        return self._perturb(t, step, key)

    def sample(
        self, rng: np.random.Generator, iters: int, workers: int, m: int
    ) -> np.ndarray:
        t = self.base.sample(rng, iters, workers, m)
        return np.stack([self._perturb(t[i], i, self.seed) for i in range(iters)])

    def _perturb(self, t: np.ndarray, step: int, key: int) -> np.ndarray:
        for i, f in enumerate(self.faults):
            t = f.perturb(t, step, np.random.default_rng([key, step, i]))
        return t

    def host_delay_at(
        self, step: int, rank: int, workers: int, m: int, seed: Optional[int] = None
    ) -> float:
        """Injected extra seconds for ``rank`` at ``step`` (perturbed minus
        base step time) — what a real SPMD launcher sleeps to turn the
        scenario into physical compute variance."""
        key = self.seed if seed is None else int(seed)
        rng = np.random.default_rng([key, step])
        base = self.base.sample(rng, 1, workers, m)[0]
        delta = self._perturb(base.copy(), step, key) - base
        return float(np.clip(delta[rank % workers].sum(), 0.0, None))

    # LatencyModel-compatible summary stats (used by theory plug-ins)
    @property
    def mean(self) -> float:
        return self.base.mean

    @property
    def std(self) -> float:
        return self.base.std


# ---------------------------------------------------------------------------
# Scenario registry (shared by launch/train.py, benchmarks, examples)
# ---------------------------------------------------------------------------

_MILD = LatencyModel(base=0.45, noise=NoiseModel(kind="normal", mean=0.1, var=0.002))
_PAPER = LatencyModel(base=0.45, noise=NoiseModel(kind="paper_lognormal"))

SCENARIOS: Dict[str, Tuple[Fault, ...]] = {
    # no tail: the controller must be a no-op here (parity scenario)
    "none": (),
    # heavy Pareto tail plus a steep mid-run base ramp: after the ramp the
    # whole latency scale (tails included) moves up 2.5x, so a tau
    # calibrated once pre-ramp sits far below the new tau* and its
    # completion collapses — the acceptance scenario where online tau must
    # beat both tau=inf and the one-shot static calibration
    "pareto": (ParetoTail(alpha=1.8, scale=0.6, prob=0.25), RampSlowdown(start=40, factor=2.5)),
    # pure heavy log-normal tail, stationary
    "lognormal": (LogNormalTail(mu=-0.5, sigma=1.2, prob=0.25),),
    # one rank goes bad mid-run
    "badnode": (BadNode(rank=2, factor=2.5, start=30),),
    # rare long whole-step stalls
    "stall": (TransientStall(prob=0.1, stall_s=4.0),),
}


def make_scenario(
    name: str,
    base: Optional[LatencyModel] = None,
    seed: int = 0,
    onset: Optional[int] = None,
) -> FaultyLatencyModel:
    """Build the named fault scenario over ``base`` (default: a mild
    low-variance cluster, so the *faults* are the tail).  ``onset``
    overrides the step at which mid-run faults (ramp/badnode) kick in."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; pick from {sorted(SCENARIOS)}")
    faults = SCENARIOS[name]
    if onset is not None:
        moved = []
        for f in faults:
            if isinstance(f, (RampSlowdown, BadNode)):
                f = dataclasses.replace(f, start=onset)
            moved.append(f)
        faults = tuple(moved)
    if base is None:
        base = _PAPER if name == "lognormal" else _MILD
    return FaultyLatencyModel(base=base, faults=faults, seed=seed)
