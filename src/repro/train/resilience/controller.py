"""Online Algorithm-2 tau controller.

``trainer.py``'s original threshold selection ran once, on a fixed
calibration window, and never revisited tau.  ``TauController`` re-runs
the Algorithm-2 grid search on the telemetry ring-buffer window every
``check_every`` steps, so tau tracks the cluster: a rank that goes bad, a
base-rate ramp, or a tail that appears mid-run all move tau* — and a run
with *no* tail keeps tau = inf (the controller is a no-op by
construction, which the parity tests pin).

Changing tau is not free on the SPMD path: the drop mask is traced with
tau baked in, so every change costs a ``build_bundle(tau)`` recompile.
Three gates stand between a candidate tau* and an applied one:

* **gain gate** — the candidate's effective speedup over the window must
  beat holding the current tau by ``min_gain`` (this is what makes the
  no-tail case a structural no-op: with zero variance S_eff(tau) <= ~1
  everywhere, no candidate clears the bar);
* **hysteresis** — relative tau moves under ``hysteresis`` are noise,
  hold;
* **recompile amortization** — the predicted per-step time saving (via
  ``core.theory``'s effective-speedup model, empirical E[T] plugged in)
  times the steps remaining must exceed ``recompile_cost_s``.

Drop-rate guardrails ride on ``select_threshold``: candidates are
restricted to completion >= 1 - ``max_drop`` and the traced mask keeps
honoring ``min_microbatches``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ...core import theory
from ...core.threshold import select_threshold
from .telemetry import ComputeTelemetry


def effective_speedup_at(
    window: np.ndarray, tc: float, tau: float, min_microbatches: int = 1
) -> Tuple[float, float]:
    """Empirical (S_eff, completion) of holding ``tau`` over a (W, N, M)
    latency window — the same arithmetic as ``SimResult.effective_speedup``
    without materializing a SimResult."""
    t = np.asarray(window, dtype=np.float64)
    t_n = t.sum(axis=-1)  # (W, N)
    t_i = t_n.max(axis=-1)  # (W,)
    if not np.isfinite(tau):
        return 1.0, 1.0
    cum = np.cumsum(t, axis=-1)
    done = cum < tau
    if min_microbatches > 0:
        done |= np.arange(t.shape[-1]) < min_microbatches
    counts = done.sum(axis=-1)  # (W, N)
    frac = counts.mean(axis=-1) / t.shape[-1]  # (W,)
    w_time = np.take_along_axis(cum, np.maximum(counts - 1, 0)[..., None], axis=-1)[..., 0]
    forced = np.where(counts > 0, w_time, 0.0).max(axis=-1)  # (W,)
    t_iter = np.maximum(np.minimum(t_i, tau), forced) + tc
    s = (t_i + tc) / t_iter * frac
    return float(s.mean()), float(frac.mean())


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Knobs for the online tau controller."""

    warmup_steps: int = 16  # min telemetry window fill before deciding
    check_every: int = 8  # steps between decisions
    hysteresis: float = 0.05  # hold when |tau_new - tau| / tau < this
    min_gain: float = 0.02  # hold when S_eff gain over current < this
    # cost one tau change must amortize; None = auto (the trainer plugs in
    # its measured bundle-build time on the SPMD path, 0 on the
    # single-device path where the mask is a step *input* and tau is free)
    recompile_cost_s: Optional[float] = None
    max_drop: float = 0.5  # guardrail: completion >= 1 - max_drop
    min_microbatches: int = 1
    grid_size: int = 128


@dataclasses.dataclass
class Decision:
    """Outcome of one controller evaluation (applied or gated)."""

    step: int
    tau: float  # candidate tau* from the window (current tau when no candidate)
    applied: bool
    reason: str  # applied | warmup | cadence | no_gain | hysteresis | not_amortized
    speedup: float = 1.0  # predicted S_eff at the candidate
    current_speedup: float = 1.0  # S_eff of holding the current tau
    gain_per_step_s: float = 0.0  # predicted effective seconds saved/step
    predicted_completion: float = 1.0


class TauController:
    """Re-estimates tau* online from a ``ComputeTelemetry`` window."""

    def __init__(
        self,
        cfg: ControllerConfig,
        tc: float,
        tau: float = float("inf"),
        total_steps: Optional[int] = None,
        default_recompile_cost_s: float = 0.0,
    ):
        self.cfg = cfg
        self.tc = float(tc)
        self.tau = float(tau)
        self.total_steps = total_steps
        self.recompile_cost_s = (
            cfg.recompile_cost_s
            if cfg.recompile_cost_s is not None
            else float(default_recompile_cost_s)
        )
        self.trajectory: List[Tuple[int, float]] = [(0, self.tau)]
        self.decisions: List[Decision] = []
        self.rebuilds = 0
        self._last_check = -1

    # -- the decision -------------------------------------------------------

    def maybe_update(
        self,
        step: int,
        telemetry: ComputeTelemetry,
        steps_remaining: Optional[int] = None,
    ) -> Decision:
        """Evaluate the window at ``step``; apply tau* if every gate passes.

        Returns the full Decision either way (``applied`` tells the caller
        whether to rebuild its step bundle).
        """
        d = self._evaluate(step, telemetry, steps_remaining)
        self.decisions.append(d)
        if d.applied:
            self.tau = d.tau
            self.trajectory.append((step, d.tau))
            self.rebuilds += 1
        return d

    def _evaluate(
        self, step: int, telemetry: ComputeTelemetry, steps_remaining: Optional[int]
    ) -> Decision:
        cfg = self.cfg
        if telemetry.window_size < max(cfg.warmup_steps, 2):
            return Decision(step, self.tau, False, "warmup")
        if self._last_check >= 0 and step - self._last_check < cfg.check_every:
            return Decision(step, self.tau, False, "cadence")
        self._last_check = step

        window = telemetry.window()  # (W, N, M)
        res = select_threshold(
            window,
            self.tc,
            grid_size=cfg.grid_size,
            min_microbatches=cfg.min_microbatches,
            max_drop=cfg.max_drop,
        )
        cand, s_cand = res.tau, res.speedup
        comp = float(res.completion[int(np.argmin(np.abs(res.grid - cand)))])
        s_cur, _ = effective_speedup_at(window, self.tc, self.tau, cfg.min_microbatches)

        if s_cand < s_cur + cfg.min_gain:
            # includes the no-tail case: zero variance => S_eff ~ 1
            # everywhere, no candidate clears the bar, tau stays put
            return Decision(step, cand, False, "no_gain", s_cand, s_cur, 0.0, comp)
        if np.isfinite(self.tau) and abs(cand - self.tau) / self.tau < cfg.hysteresis:
            return Decision(step, cand, False, "hysteresis", s_cand, s_cur, 0.0, comp)

        gain = self._predicted_gain_s(window, s_cand, s_cur)
        remaining = steps_remaining
        if remaining is None:
            remaining = (self.total_steps - step) if self.total_steps else 1
        if gain * max(remaining, 0) <= self.recompile_cost_s:
            return Decision(step, cand, False, "not_amortized", s_cand, s_cur, gain, comp)
        return Decision(step, cand, True, "applied", s_cand, s_cur, gain, comp)

    def _predicted_gain_s(self, window: np.ndarray, s_cand: float, s_cur: float) -> float:
        """Predicted *effective* seconds saved per step by moving to the
        candidate, via the theory effective-speedup model (eq. 11):

            S_eff(tau) = (E[T] + tc) / t_eff(tau)   =>
            t_eff(tau) = (E[T] + tc) / S_eff(tau)

        with the empirical E[T] and window S_eff estimates plugged in —
        the pure-Gaussian E[M~] of ``theory.expected_completed_microbatches``
        under-counts completion on heavy (Pareto) tails (the fig. 3b
        caveat), which would wedge the controller at a stale tau, so the
        model is evaluated at the measured quantities instead."""
        t = np.asarray(window, dtype=np.float64)
        e_t = float(t.sum(axis=-1).max(axis=-1).mean())
        return (e_t + self.tc) * (1.0 / max(s_cur, 1e-9) - 1.0 / max(s_cand, 1e-9))

    # -- persistence --------------------------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        return {
            "tau": self.tau if np.isfinite(self.tau) else None,
            "tc": self.tc,
            "trajectory": [
                [int(s), (t if np.isfinite(t) else None)] for s, t in self.trajectory
            ],
            "rebuilds": self.rebuilds,
            "last_check": self._last_check,
            "cfg": dataclasses.asdict(self.cfg),
        }

    def load_state_dict(self, s: Dict[str, Any]) -> None:
        self.tau = float("inf") if s["tau"] is None else float(s["tau"])
        self.tc = float(s.get("tc", self.tc))
        self.trajectory = [
            (int(st), float("inf") if t is None else float(t))
            for st, t in s.get("trajectory", [[0, None]])
        ]
        self.rebuilds = int(s.get("rebuilds", 0))
        self._last_check = int(s.get("last_check", -1))
