"""Compute-time telemetry: bounded buffers + streaming estimators.

Collects the per-worker, per-micro-batch latency tensor of every training
step — simulated draws from a ``LatencyModel`` or real host timings (the
monotonic clock around the jitted step, or ``HostTimedEngine``'s
per-micro-batch log) — and keeps

* a **ring buffer** of the most recent ``window`` steps (the rolling
  Algorithm-2 profile the online controller re-estimates tau* from), and
* **streaming** mean/std (Welford) and P² percentile estimators over the
  whole run, so long runs get lifetime statistics at O(1) memory.

Everything is host-side numpy; nothing here is traced.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class RingBuffer:
    """Fixed-capacity ring of equally-shaped numpy records.

    ``push`` overwrites the oldest entry once full; ``window()`` returns
    the retained records oldest-first.  The buffer never holds more than
    ``capacity`` records (the bound the property tests pin).
    """

    def __init__(self, capacity: int, shape: Tuple[int, ...] = ()):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.shape = tuple(shape)
        self._buf = np.zeros((self.capacity, *self.shape), dtype=np.float64)
        self._n = 0  # total pushes ever
        self._head = 0  # next write position

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def total_pushed(self) -> int:
        return self._n

    def push(self, rec) -> None:
        rec = np.asarray(rec, dtype=np.float64)
        if rec.shape != self.shape:
            raise ValueError(f"record shape {rec.shape} != buffer shape {self.shape}")
        self._buf[self._head] = rec
        self._head = (self._head + 1) % self.capacity
        self._n += 1

    def window(self) -> np.ndarray:
        """(k, *shape) retained records, oldest first (k <= capacity)."""
        k = len(self)
        if self._n <= self.capacity:
            return self._buf[:k].copy()
        return np.roll(self._buf, -self._head, axis=0).copy()

    def clear(self) -> None:
        self._n = 0
        self._head = 0


class StreamingMoments:
    """Welford's online mean/variance over scalars or flattened arrays."""

    def __init__(self):
        self.count = 0
        self._mean = 0.0
        self._m2 = 0.0

    def push(self, x) -> None:
        for v in np.asarray(x, dtype=np.float64).ravel():
            self.count += 1
            d = v - self._mean
            self._mean += d / self.count
            self._m2 += d * (v - self._mean)

    @property
    def mean(self) -> float:
        return float(self._mean)

    @property
    def var(self) -> float:
        return float(self._m2 / self.count) if self.count > 1 else 0.0

    @property
    def std(self) -> float:
        return float(np.sqrt(self.var))

    def state_dict(self) -> Dict[str, float]:
        return {"count": int(self.count), "mean": float(self._mean), "m2": float(self._m2)}

    def load_state_dict(self, s: Dict[str, float]) -> None:
        self.count = int(s["count"])
        self._mean = float(s["mean"])
        self._m2 = float(s["m2"])


class P2Quantile:
    """Jain & Chlamtac's P² streaming quantile estimator.

    Five markers, O(1) per observation; exact until five samples have
    arrived, then a piecewise-parabolic approximation.  Good to a few
    percent on the smooth unimodal step-time distributions telemetry
    sees — the controller uses the ring-buffer window (exact) for tau*
    and these only for lifetime summaries and checkpointed state.
    """

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = float(q)
        self._init: List[float] = []
        self._h: Optional[np.ndarray] = None  # marker heights
        self._pos: Optional[np.ndarray] = None  # marker positions
        self._want: Optional[np.ndarray] = None  # desired positions
        self._dwant = np.array([0.0, self.q / 2, self.q, (1 + self.q) / 2, 1.0])

    @property
    def count(self) -> int:
        return len(self._init) if self._h is None else int(self._pos[-1])

    def push(self, x) -> None:
        for v in np.asarray(x, dtype=np.float64).ravel():
            self._push_one(float(v))

    def _push_one(self, v: float) -> None:
        if self._h is None:
            self._init.append(v)
            if len(self._init) == 5:
                self._h = np.sort(np.array(self._init))
                self._pos = np.arange(1.0, 6.0)
                self._want = np.array(
                    [1.0, 1 + 2 * self.q, 1 + 4 * self.q, 3 + 2 * self.q, 5.0]
                )
            return
        h, pos = self._h, self._pos
        if v < h[0]:
            h[0] = v
            k = 0
        elif v >= h[4]:
            h[4] = v
            k = 3
        else:
            k = int(np.searchsorted(h, v, side="right")) - 1
        pos[k + 1 :] += 1.0
        self._want += self._dwant
        # adjust the three interior markers
        for i in (1, 2, 3):
            d = self._want[i] - pos[i]
            if (d >= 1 and pos[i + 1] - pos[i] > 1) or (d <= -1 and pos[i - 1] - pos[i] < -1):
                s = 1.0 if d >= 0 else -1.0
                hp = self._parabolic(i, s)
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:  # fall back to linear
                    j = i + int(s)
                    h[i] = h[i] + s * (h[j] - h[i]) / (pos[j] - pos[i])
                pos[i] += s

    def _parabolic(self, i: int, s: float) -> float:
        h, p = self._h, self._pos
        return h[i] + s / (p[i + 1] - p[i - 1]) * (
            (p[i] - p[i - 1] + s) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
            + (p[i + 1] - p[i] - s) * (h[i] - h[i - 1]) / (p[i] - p[i - 1])
        )

    @property
    def value(self) -> float:
        if self._h is not None:
            return float(self._h[2])
        if not self._init:
            return float("nan")
        return float(np.quantile(np.array(self._init), self.q))

    def state_dict(self) -> Dict[str, Any]:
        return {
            "q": self.q,
            "init": list(self._init),
            "h": None if self._h is None else self._h.tolist(),
            "pos": None if self._pos is None else self._pos.tolist(),
            "want": None if self._want is None else self._want.tolist(),
        }

    def load_state_dict(self, s: Dict[str, Any]) -> None:
        self.q = float(s["q"])
        self._init = list(s["init"])
        self._h = None if s["h"] is None else np.array(s["h"], dtype=np.float64)
        self._pos = None if s["pos"] is None else np.array(s["pos"], dtype=np.float64)
        self._want = None if s["want"] is None else np.array(s["want"], dtype=np.float64)


@dataclasses.dataclass
class StepRecord:
    """One training step's compute-time observation (exportable)."""

    step: int
    worker_time: List[float]  # (N,) per-worker step compute seconds
    host_step_s: Optional[float]  # wall seconds around the jitted step
    tau: float
    drop_fraction: float

    def to_dict(self) -> Dict[str, Any]:
        return {
            "step": self.step,
            "worker_time": [round(float(t), 6) for t in self.worker_time],
            "host_step_s": None if self.host_step_s is None else round(self.host_step_s, 6),
            "tau": self.tau if np.isfinite(self.tau) else None,
            "drop_fraction": round(self.drop_fraction, 6),
        }


_DEFAULT_QUANTILES = (0.5, 0.9, 0.99)


class ComputeTelemetry:
    """Rolling + lifetime view of per-worker compute times.

    ``record`` ingests one step's (N, M) latency tensor; ``window()``
    hands the controller the (W, N, M) rolling profile it re-runs
    Algorithm 2 on.  Micro-batch moments, worker-step-time quantiles AND
    the rolling window survive checkpoints via ``state_dict`` /
    ``load_state_dict``, so a resumed run's controller decides from the
    same profile the uninterrupted run would have seen.
    """

    def __init__(
        self,
        n_workers: int,
        microbatches: int,
        window: int = 64,
        quantiles: Sequence[float] = _DEFAULT_QUANTILES,
        keep_records: int = 4096,
    ):
        self.n_workers = int(n_workers)
        self.microbatches = int(microbatches)
        self._steps_total = 0
        self._ring = RingBuffer(window, (self.n_workers, self.microbatches))
        self.mb_moments = StreamingMoments()  # per-micro-batch seconds
        self.step_moments = StreamingMoments()  # per-worker step seconds
        self.host_moments = StreamingMoments()  # measured host wall seconds
        self.quantiles = {q: P2Quantile(q) for q in quantiles}
        self._record_meta: List[StepRecord] = []
        self._keep_records = int(keep_records)

    # -- ingestion ----------------------------------------------------------

    def record(
        self,
        step: int,
        latencies: np.ndarray,
        host_step_s: Optional[float] = None,
        tau: float = float("inf"),
        drop_fraction: float = 0.0,
    ) -> None:
        t = np.asarray(latencies, dtype=np.float64)
        if t.shape != (self.n_workers, self.microbatches):
            raise ValueError(
                f"latencies {t.shape} != (N={self.n_workers}, M={self.microbatches})"
            )
        self._ring.push(t)
        self._steps_total += 1
        self.mb_moments.push(t)
        per_worker = t.sum(axis=-1)
        self.step_moments.push(per_worker)
        for p2 in self.quantiles.values():
            p2.push(per_worker)
        if host_step_s is not None:
            self.host_moments.push(host_step_s)
        self._record_meta.append(
            StepRecord(step, per_worker.tolist(), host_step_s, float(tau), float(drop_fraction))
        )
        if len(self._record_meta) > self._keep_records:
            self._record_meta = self._record_meta[-self._keep_records :]

    def ingest_host_profile(self, profile: np.ndarray, start_step: int = 0) -> None:
        """Reconcile a ``HostTimedEngine.profile()`` tensor ((I, 1, M),
        ragged rows NaN-padded: micro-batches the engine *dropped*).

        NaNs are filled with the column's observed mean so the window
        stays a dense Algorithm-2 profile — the same convention
        ``core.threshold`` applies.
        """
        prof = np.asarray(profile, dtype=np.float64)
        if prof.ndim != 3:
            raise ValueError(f"profile must be (I, N, M), got {prof.shape}")
        from ...core.threshold import fill_profile_nans

        prof = fill_profile_nans(prof)
        if prof.shape[1] == 1 and self.n_workers > 1:
            prof = np.broadcast_to(prof, (prof.shape[0], self.n_workers, prof.shape[2]))
        if prof.shape[1:] != (self.n_workers, self.microbatches):
            raise ValueError(
                f"profile {prof.shape} incompatible with (N={self.n_workers}, "
                f"M={self.microbatches})"
            )
        for i in range(prof.shape[0]):
            self.record(start_step + i, prof[i])

    # -- views --------------------------------------------------------------

    @property
    def steps(self) -> int:
        return self._steps_total

    @property
    def window_size(self) -> int:
        return len(self._ring)

    def window(self) -> np.ndarray:
        """(W, N, M) rolling latency profile, oldest first."""
        return self._ring.window()

    def summary(self) -> Dict[str, Any]:
        return {
            "steps": self.steps,
            "window": self.window_size,
            "mb_mean_s": self.mb_moments.mean,
            "mb_std_s": self.mb_moments.std,
            "worker_step_mean_s": self.step_moments.mean,
            "worker_step_std_s": self.step_moments.std,
            "host_step_mean_s": self.host_moments.mean if self.host_moments.count else None,
            "worker_step_quantiles_s": {
                f"p{int(q * 100)}": p2.value for q, p2 in self.quantiles.items()
            },
        }

    def export_records(self) -> List[Dict[str, Any]]:
        return [r.to_dict() for r in self._record_meta]

    # -- persistence (checkpointed alongside the controller) ---------------

    def state_dict(self) -> Dict[str, Any]:
        return {
            "n_workers": self.n_workers,
            "microbatches": self.microbatches,
            "steps": self.steps,
            "mb_moments": self.mb_moments.state_dict(),
            "step_moments": self.step_moments.state_dict(),
            "host_moments": self.host_moments.state_dict(),
            "quantiles": {str(q): p2.state_dict() for q, p2 in self.quantiles.items()},
            # the rolling window rides along (W*N*M floats) so a resumed
            # run's controller sees the *same* profile the uninterrupted
            # run would — the restore-parity contract
            "window": self._ring.window().tolist(),
        }

    def load_state_dict(self, s: Dict[str, Any]) -> None:
        self.mb_moments.load_state_dict(s["mb_moments"])
        self.step_moments.load_state_dict(s["step_moments"])
        self.host_moments.load_state_dict(s["host_moments"])
        for q, p2 in self.quantiles.items():
            key = str(q)
            if key in s.get("quantiles", {}):
                p2.load_state_dict(s["quantiles"][key])
        self._steps_total = int(s.get("steps", 0))
        self._ring.clear()
        for rec in np.asarray(s.get("window", []), dtype=np.float64).reshape(
            -1, self.n_workers, self.microbatches
        ):
            self._ring.push(rec)
