from . import checkpoint
from .trainer import TrainConfig, TrainResult, train

__all__ = ["checkpoint", "TrainConfig", "TrainResult", "train"]
