"""Training loop with DropCompute as a first-class feature.

The trainer virtualizes N data-parallel workers on whatever devices exist:
each step draws a (N, M) micro-batch latency tensor from a ``LatencyModel``
(or a ``resilience.faults`` scenario wrapping one), derives the Algorithm-1
drop mask, and accumulates masked gradients.  Simulated iteration time

    T_iter = max_n min(T_n, tau) + T_c

is tracked per step so loss-vs-wallclock curves (paper fig. 5) come out of
any run.

Threshold selection runs in one of two modes:

* **static** (``auto_threshold=True``): the original one-shot Algorithm 2
  after ``calibration_steps`` profiling steps;
* **online** (``online_tau=True``): a ``resilience.TauController``
  re-estimates tau* from the rolling telemetry window during the run —
  with hysteresis, drop guardrails and a recompile-amortization gate,
  since on the SPMD path tau is baked into the traced drop mask and every
  change costs a ``build_bundle`` rebuild.

Per-step compute telemetry (simulated draws reconciled with the monotonic
host clock around the jitted step) is always collected; the controller
state and telemetry summary ride checkpoints, so a restarted run resumes
with its adapted tau instead of re-calibrating from scratch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dropcompute import DropConfig, accumulate_grads, drop_mask
from ..core.engine import make_grad_fn
from ..core.simulate import LatencyModel
from ..core.threshold import select_threshold
from ..data.synthetic import DataConfig, batch_at, microbatches_at
from ..dist import Distribution
from ..models import InputShape, ModelConfig, init_params, loss_fn
from ..optim import apply_updates, clip_by_global_norm, make as make_opt
from . import checkpoint as ckpt
from .resilience import ComputeTelemetry, ControllerConfig, TauController

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    n_workers: int = 8  # virtual data-parallel workers
    microbatches: int = 4  # M (gradient accumulations per worker)
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    seed: int = 0
    # DropCompute
    drop: DropConfig = dataclasses.field(default_factory=lambda: DropConfig(enabled=False))
    latency: LatencyModel = dataclasses.field(default_factory=LatencyModel)
    tc: float = 0.5  # serial/communication seconds per iteration
    calibration_steps: int = 20  # Algorithm 2 profiling window
    auto_threshold: bool = False  # static: one-shot tau* after calibration
    # Online tau (repro.train.resilience): re-estimate tau* from rolling
    # telemetry during the run; ``controller`` overrides the default knobs.
    online_tau: bool = False
    controller: Optional[ControllerConfig] = None
    telemetry_window: int = 64
    # Fault scenarios already live in ``latency`` (a FaultyLatencyModel);
    # set this to additionally *sleep* the injected delays around the real
    # step (physical compute variance on SPMD runs).
    inject_real_delays: bool = False
    # Distribution: None = single-device virtual-worker loop; a mesh spec
    # ("4,2", a dim tuple, or a repro.dist.Distribution) switches to the
    # sharded SPMD step built by ``Distribution.train_step``.
    mesh: Optional[Any] = None
    # bookkeeping
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    resume_from: Optional[str] = None  # checkpoint dir to resume from


@dataclasses.dataclass
class TrainResult:
    params: PyTree
    losses: List[float]
    sim_times: List[float]  # simulated seconds per iteration
    drop_fractions: List[float]  # per-step drop rate (1 - completed fraction)
    tau: float  # final threshold (back-compat scalar)
    metrics: Dict[str, Any]
    # (step, tau) at every threshold change, starting with the initial tau;
    # the full trajectory, not just the final scalar.
    tau_trajectory: List[Tuple[int, float]] = dataclasses.field(default_factory=list)
    telemetry: Optional[Dict[str, Any]] = None  # ComputeTelemetry.summary()

    @property
    def cum_time(self) -> np.ndarray:
        return np.cumsum(self.sim_times)

    @property
    def drop_rates(self) -> List[float]:
        """Per-step drop rate; alias of ``drop_fractions`` under the name
        the benchmark/figure scripts use."""
        return self.drop_fractions

    def tau_series(self, start_step: int = 0) -> np.ndarray:
        """Per-step tau in effect, aligned with ``losses`` (len(losses),)."""
        n = len(self.losses)
        out = np.full(n, np.inf)
        traj = self.tau_trajectory or [(start_step, self.tau)]
        for step, tau in traj:
            i = max(int(step) - start_step, 0)
            if i < n:
                out[i:] = tau
        return out


def _resolve_dist(mesh) -> Optional[Distribution]:
    """None | "4,2" | (4, 2) | Mesh | Distribution -> Optional[Distribution]."""
    if mesh is None:
        return None
    if isinstance(mesh, Distribution):
        return mesh
    if isinstance(mesh, jax.sharding.Mesh):
        return Distribution(mesh)
    return Distribution.from_spec(mesh)


def _make_step(model_cfg: ModelConfig, tcfg: TrainConfig, lr_fn):
    opt = make_opt(
        tcfg.optimizer, lr_fn, weight_decay=tcfg.weight_decay
    ) if tcfg.optimizer != "sgd" else make_opt(tcfg.optimizer, lr_fn)
    grad_fn = make_grad_fn(lambda p, mb: loss_fn(p, model_cfg, mb))

    def step(params, opt_state, microbatch_stack, mask):
        grads, loss, stats = accumulate_grads(
            grad_fn, params, microbatch_stack, mask, tcfg.drop
        )
        if tcfg.clip_norm > 0:
            grads = clip_by_global_norm(grads, tcfg.clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, stats

    return opt, jax.jit(step)


def _latencies_at(tcfg: TrainConfig, step: int, n: int, m: int) -> np.ndarray:
    """The step's (N, M) latency draw, keyed by (seed, step) so resumed
    runs replay the identical stream (``sample_at`` seam on both
    ``LatencyModel`` and ``resilience.FaultyLatencyModel``)."""
    return np.asarray(tcfg.latency.sample_at(step, n, m, seed=tcfg.seed + 1))


def train(
    model_cfg: ModelConfig,
    data_cfg: DataConfig,
    tcfg: TrainConfig,
    params: Optional[PyTree] = None,
    eval_fn: Optional[Callable[[PyTree], float]] = None,
) -> TrainResult:
    n, m = tcfg.n_workers, tcfg.microbatches
    total_m = n * m
    assert data_cfg.batch_size % total_m == 0, (
        f"global batch {data_cfg.batch_size} must divide into {n} workers x {m} microbatches"
    )

    if params is None:
        params = init_params(jax.random.PRNGKey(tcfg.seed), model_cfg)

    # --- distribution: resolve the SPMD path up front --------------------
    dist = _resolve_dist(tcfg.mesh)
    bundle = None
    build_s = 0.0  # measured bundle-build cost (the recompile the gate amortizes)
    if dist is not None:
        shape = InputShape(
            "train_cli", data_cfg.seq_len, data_cfg.batch_size, "train",
            microbatches=m,
        )

        def build_bundle(tau_now: float):
            drop = dataclasses.replace(tcfg.drop, tau=tau_now)
            # sgd: no decay, mirroring _make_step's single-device path —
            # the same TrainConfig must train identically on both paths
            wd = None if tcfg.optimizer == "sgd" else tcfg.weight_decay
            return dist.train_step(
                model_cfg, shape, drop, n_workers=n,
                optimizer=tcfg.optimizer, lr=tcfg.lr,
                clip_norm=tcfg.clip_norm, weight_decay=wd,
            )

        b0 = time.monotonic()
        bundle = build_bundle(tcfg.drop.tau)
        build_s = time.monotonic() - b0
        opt = bundle.opt
        params = dist.shard(params)
        opt_state = opt.init(params)
    else:
        opt, step_fn = _make_step(model_cfg, tcfg, lambda s: tcfg.lr)
        opt_state = opt.init(params)

    tau = tcfg.drop.tau
    profile: List[np.ndarray] = []

    # --- resilience: telemetry always on, controller when online_tau -----
    telemetry = ComputeTelemetry(n, m, window=tcfg.telemetry_window)
    controller: Optional[TauController] = None
    if tcfg.online_tau and tcfg.drop.enabled:
        ccfg = tcfg.controller or ControllerConfig(
            min_microbatches=tcfg.drop.min_microbatches
        )
        controller = TauController(
            ccfg, tcfg.tc, tau=tau, total_steps=tcfg.steps,
            default_recompile_cost_s=build_s if bundle is not None else 0.0,
        )

    # --- resume: params/opt/step plus the adapted tau + controller state --
    start_step = 0
    if tcfg.resume_from:
        restored, start_step = ckpt.restore(
            tcfg.resume_from, {"params": params, "opt": opt_state}
        )
        params, opt_state = restored["params"], restored["opt"]
        if dist is not None:
            params = dist.shard(params)
        state = ckpt.resilience_state(tcfg.resume_from)
        if state:
            tau = float("inf") if state.get("tau") is None else float(state["tau"])
            if controller is not None and state.get("controller"):
                controller.load_state_dict(state["controller"])
                tau = controller.tau
            if state.get("telemetry"):
                telemetry.load_state_dict(state["telemetry"])
            if bundle is not None and tau != tcfg.drop.tau:
                bundle = build_bundle(tau)

    trajectory: List[Tuple[int, float]] = [(start_step, tau)]

    def _save_ckpt(step_now: int):
        res_state = {
            "tau": None if not np.isfinite(tau) else float(tau),
            "controller": controller.state_dict() if controller else None,
            "telemetry": telemetry.state_dict(),
            "trajectory": [
                [int(s), (None if not np.isfinite(t) else float(t))]
                for s, t in (controller.trajectory if controller else trajectory)
            ],
        }
        ckpt.save(
            tcfg.ckpt_dir, {"params": params, "opt": opt_state}, step_now,
            extra={"resilience": res_state},
        )

    losses, sim_times, drops = [], [], []
    for step in range(start_step, tcfg.steps):
        if dist is None:
            mbs = microbatches_at(step, data_cfg, total_m)
            mbs = {k: jnp.asarray(v) for k, v in mbs.items() if k != "lengths"}
        else:
            b = batch_at(step, data_cfg)
            mbs = {k: jnp.asarray(b[k]) for k in ("tokens", "weights")}

        # --- latency draws for the N virtual workers (Algorithm 1 input) ---
        t = _latencies_at(tcfg, step, n, m)
        profile.append(t)

        # --- threshold selection -------------------------------------------
        # static: one-shot Algorithm 2 after the calibration window
        if (
            tcfg.auto_threshold
            and not tcfg.online_tau
            and tcfg.drop.enabled
            and not np.isfinite(tau)
            and step == tcfg.calibration_steps
        ):
            prof = np.stack(profile)  # (I, N, M)
            res = select_threshold(prof, tcfg.tc)
            tau = res.tau
            trajectory.append((step, tau))
            if bundle is not None:
                # tau is baked into the traced drop mask: rebuild (one
                # recompile per calibration, not per step)
                bundle = build_bundle(tau)

        # online: the controller re-estimates tau* from the rolling window
        if controller is not None:
            decision = controller.maybe_update(
                step, telemetry, steps_remaining=tcfg.steps - step
            )
            if decision.applied:
                tau = decision.tau
                trajectory.append((step, tau))
                if bundle is not None:
                    bundle = build_bundle(tau)

        # --- drop mask (per worker), flattened onto the microbatch axis ---
        if tcfg.drop.enabled and np.isfinite(tau):
            mask_nm = np.asarray(
                drop_mask(jnp.asarray(t), tau, tcfg.drop.min_microbatches)
            )
        else:
            mask_nm = np.ones((n, m), np.float32)

        # --- optionally turn the scenario into physical delay --------------
        if tcfg.inject_real_delays and hasattr(tcfg.latency, "host_delay_at"):
            worst = max(
                tcfg.latency.host_delay_at(step, r, n, m, seed=tcfg.seed + 1)
                for r in range(n)
            )
            if worst > 0:
                time.sleep(worst)

        h0 = time.monotonic()
        if bundle is not None:
            params, opt_state, metrics = bundle(params, opt_state, mbs, jnp.asarray(t))
            loss = metrics["loss"]
            stats = {"completed_fraction": metrics["completed_fraction"]}
        else:
            mask = jnp.asarray(mask_nm.reshape(total_m))
            params, opt_state, loss, stats = step_fn(params, opt_state, mbs, mask)
        jax.block_until_ready(loss)
        host_step_s = time.monotonic() - h0

        # --- simulated iteration time (eq. in §4.3) ---
        t_workers = (t * mask_nm).sum(axis=-1)  # compute actually performed
        t_iter = float(t_workers.max() + tcfg.tc) if tcfg.drop.enabled and np.isfinite(tau) else float(
            t.sum(axis=-1).max() + tcfg.tc
        )
        drop_frac = 1.0 - float(stats["completed_fraction"])
        losses.append(float(loss))
        sim_times.append(t_iter)
        drops.append(drop_frac)

        telemetry.record(
            step, t, host_step_s=host_step_s, tau=tau, drop_fraction=drop_frac
        )

        if tcfg.ckpt_dir and tcfg.ckpt_every and (step + 1) % tcfg.ckpt_every == 0:
            _save_ckpt(step + 1)

    final_trajectory = list(controller.trajectory) if controller else trajectory
    metrics: Dict[str, Any] = {
        "final_loss": losses[-1] if losses else float("nan"),
        "mean_drop": float(np.mean(drops)) if drops else 0.0,
        "total_sim_time": float(np.sum(sim_times)),
        "tau_changes": max(len(final_trajectory) - 1, 0),
        "bundle_rebuilds": (controller.rebuilds if controller else 0) if bundle is not None else 0,
    }
    if eval_fn is not None:
        metrics["eval"] = float(eval_fn(params))
    return TrainResult(
        params, losses, sim_times, drops, float(tau), metrics,
        tau_trajectory=final_trajectory, telemetry=telemetry.summary(),
    )
