"""Training loop with DropCompute as a first-class feature.

The trainer virtualizes N data-parallel workers on whatever devices exist:
each step draws a (N, M) micro-batch latency tensor from a ``LatencyModel``
(or records real wall-clock times via HostTimedEngine), derives the
Algorithm-1 drop mask, and accumulates masked gradients.  Simulated
iteration time

    T_iter = max_n min(T_n, tau) + T_c

is tracked per step so loss-vs-wallclock curves (paper fig. 5) come out of
any run.  Threshold selection (Algorithm 2) runs automatically after
``calibration_steps`` profiling steps when ``drop.tau`` is unset.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dropcompute import DropConfig, accumulate_grads, drop_mask
from ..core.engine import make_grad_fn
from ..core.simulate import LatencyModel
from ..core.threshold import select_threshold
from ..data.synthetic import DataConfig, batch_at, microbatches_at
from ..dist import Distribution
from ..models import InputShape, ModelConfig, init_params, loss_fn
from ..optim import apply_updates, clip_by_global_norm, make as make_opt
from . import checkpoint as ckpt

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    n_workers: int = 8  # virtual data-parallel workers
    microbatches: int = 4  # M (gradient accumulations per worker)
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    seed: int = 0
    # DropCompute
    drop: DropConfig = dataclasses.field(default_factory=lambda: DropConfig(enabled=False))
    latency: LatencyModel = dataclasses.field(default_factory=LatencyModel)
    tc: float = 0.5  # serial/communication seconds per iteration
    calibration_steps: int = 20  # Algorithm 2 profiling window
    auto_threshold: bool = False
    # Distribution: None = single-device virtual-worker loop; a mesh spec
    # ("4,2", a dim tuple, or a repro.dist.Distribution) switches to the
    # sharded SPMD step built by ``Distribution.train_step``.
    mesh: Optional[Any] = None
    # bookkeeping
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0


@dataclasses.dataclass
class TrainResult:
    params: PyTree
    losses: List[float]
    sim_times: List[float]  # simulated seconds per iteration
    drop_fractions: List[float]
    tau: float
    metrics: Dict[str, Any]

    @property
    def cum_time(self) -> np.ndarray:
        return np.cumsum(self.sim_times)


def _resolve_dist(mesh) -> Optional[Distribution]:
    """None | "4,2" | (4, 2) | Mesh | Distribution -> Optional[Distribution]."""
    if mesh is None:
        return None
    if isinstance(mesh, Distribution):
        return mesh
    if isinstance(mesh, jax.sharding.Mesh):
        return Distribution(mesh)
    return Distribution.from_spec(mesh)


def _make_step(model_cfg: ModelConfig, tcfg: TrainConfig, lr_fn):
    opt = make_opt(
        tcfg.optimizer, lr_fn, weight_decay=tcfg.weight_decay
    ) if tcfg.optimizer != "sgd" else make_opt(tcfg.optimizer, lr_fn)
    grad_fn = make_grad_fn(lambda p, mb: loss_fn(p, model_cfg, mb))

    def step(params, opt_state, microbatch_stack, mask):
        grads, loss, stats = accumulate_grads(
            grad_fn, params, microbatch_stack, mask, tcfg.drop
        )
        if tcfg.clip_norm > 0:
            grads = clip_by_global_norm(grads, tcfg.clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, stats

    return opt, jax.jit(step)


def train(
    model_cfg: ModelConfig,
    data_cfg: DataConfig,
    tcfg: TrainConfig,
    params: Optional[PyTree] = None,
    eval_fn: Optional[Callable[[PyTree], float]] = None,
) -> TrainResult:
    n, m = tcfg.n_workers, tcfg.microbatches
    total_m = n * m
    assert data_cfg.batch_size % total_m == 0, (
        f"global batch {data_cfg.batch_size} must divide into {n} workers x {m} microbatches"
    )

    if params is None:
        params = init_params(jax.random.PRNGKey(tcfg.seed), model_cfg)

    # --- distribution: resolve the SPMD path up front --------------------
    dist = _resolve_dist(tcfg.mesh)
    bundle = None
    if dist is not None:
        shape = InputShape(
            "train_cli", data_cfg.seq_len, data_cfg.batch_size, "train",
            microbatches=m,
        )

        def build_bundle(tau_now: float):
            drop = dataclasses.replace(tcfg.drop, tau=tau_now)
            # sgd: no decay, mirroring _make_step's single-device path —
            # the same TrainConfig must train identically on both paths
            wd = None if tcfg.optimizer == "sgd" else tcfg.weight_decay
            return dist.train_step(
                model_cfg, shape, drop, n_workers=n,
                optimizer=tcfg.optimizer, lr=tcfg.lr,
                clip_norm=tcfg.clip_norm, weight_decay=wd,
            )

        bundle = build_bundle(tcfg.drop.tau)
        opt = bundle.opt
        params = dist.shard(params)
        opt_state = opt.init(params)
    else:
        opt, step_fn = _make_step(model_cfg, tcfg, lambda s: tcfg.lr)
        opt_state = opt.init(params)

    lat_rng = np.random.default_rng(tcfg.seed + 1)
    tau = tcfg.drop.tau
    profile: List[np.ndarray] = []

    losses, sim_times, drops = [], [], []
    for step in range(tcfg.steps):
        if dist is None:
            mbs = microbatches_at(step, data_cfg, total_m)
            mbs = {k: jnp.asarray(v) for k, v in mbs.items() if k != "lengths"}
        else:
            b = batch_at(step, data_cfg)
            mbs = {k: jnp.asarray(b[k]) for k in ("tokens", "weights")}

        # --- latency draws for the N virtual workers (Algorithm 1 input) ---
        t = tcfg.latency.sample(lat_rng, 1, n, m)[0]  # (N, M)
        profile.append(t)

        # --- Algorithm 2: pick tau* after the calibration window ---
        if (
            tcfg.auto_threshold
            and tcfg.drop.enabled
            and not np.isfinite(tau)
            and step == tcfg.calibration_steps
        ):
            prof = np.stack(profile)  # (I, N, M)
            res = select_threshold(prof, tcfg.tc)
            tau = res.tau
            if bundle is not None:
                # tau is baked into the traced drop mask: rebuild (one
                # recompile per calibration, not per step)
                bundle = build_bundle(tau)

        # --- drop mask (per worker), flattened onto the microbatch axis ---
        if tcfg.drop.enabled and np.isfinite(tau):
            mask_nm = np.asarray(
                drop_mask(jnp.asarray(t), tau, tcfg.drop.min_microbatches)
            )
        else:
            mask_nm = np.ones((n, m), np.float32)

        if bundle is not None:
            params, opt_state, metrics = bundle(params, opt_state, mbs, jnp.asarray(t))
            loss = metrics["loss"]
            stats = {"completed_fraction": metrics["completed_fraction"]}
        else:
            mask = jnp.asarray(mask_nm.reshape(total_m))
            params, opt_state, loss, stats = step_fn(params, opt_state, mbs, mask)

        # --- simulated iteration time (eq. in §4.3) ---
        t_workers = (t * mask_nm).sum(axis=-1)  # compute actually performed
        t_iter = float(t_workers.max() + tcfg.tc) if tcfg.drop.enabled and np.isfinite(tau) else float(
            t.sum(axis=-1).max() + tcfg.tc
        )
        losses.append(float(loss))
        sim_times.append(t_iter)
        drops.append(1.0 - float(stats["completed_fraction"]))

        if tcfg.ckpt_dir and tcfg.ckpt_every and (step + 1) % tcfg.ckpt_every == 0:
            ckpt.save(tcfg.ckpt_dir, {"params": params, "opt": opt_state}, step + 1)

    metrics: Dict[str, Any] = {
        "final_loss": losses[-1] if losses else float("nan"),
        "mean_drop": float(np.mean(drops)) if drops else 0.0,
        "total_sim_time": float(np.sum(sim_times)),
    }
    if eval_fn is not None:
        metrics["eval"] = float(eval_fn(params))
    return TrainResult(params, losses, sim_times, drops, float(tau), metrics)
