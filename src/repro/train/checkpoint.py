"""Checkpointing: pytree save/restore with step metadata.

npz-based (offline environment; no orbax).  Arrays are saved host-local;
in a multi-host deployment each process saves its addressable shards under
a process-indexed name — the seam is ``shard_suffix``.  Restore validates
structure and shapes against a template pytree.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz can't store ml_dtypes
            arr = arr.view(np.uint16)
        out[key] = arr
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(path: str, tree: PyTree, step: int, extra: Optional[dict] = None, shard_suffix: str = ""):
    os.makedirs(path, exist_ok=True)
    arrays = _flatten(tree)
    np.savez(os.path.join(path, f"arrays{shard_suffix}.npz"), **arrays)
    meta = {"step": int(step), "extra": extra or {}, "keys": sorted(arrays)}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f, default=float)  # numpy scalars in extra


def restore(path: str, template: PyTree, shard_suffix: str = "") -> Tuple[PyTree, int]:
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, f"arrays{shard_suffix}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for pth, leaf in flat:
        key = _SEP.join(_path_str(p) for p in pth)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if str(leaf.dtype) == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
    return tree, meta["step"]


def latest_step(path: str) -> Optional[int]:
    meta = os.path.join(path, "meta.json")
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        return json.load(f)["step"]


def load_extra(path: str) -> Optional[dict]:
    """The ``extra`` metadata dict saved alongside the arrays (``None`` if
    no checkpoint exists).  The trainer keeps its tau-controller state here
    — current tau, tau trajectory, telemetry summary — so a restarted run
    resumes with its *adapted* threshold instead of re-calibrating."""
    meta = os.path.join(path, "meta.json")
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        return json.load(f).get("extra") or {}


def resilience_state(path: str) -> Optional[dict]:
    """Convenience accessor for the tau-controller/telemetry state blob
    (see ``trainer.train``'s checkpoint writes)."""
    extra = load_extra(path)
    if not extra:
        return None
    return extra.get("resilience")
