"""BERT-Large — the paper's §5.1 generalization model  [Devlin et al. 2018].

24L d_model=1024 16H d_ff=4096 vocab=30522, bidirectional encoder.
Modelled here as a decoder-free stack of 'B' blocks with an LM head
(our synthetic-data CE objective stands in for MLM; the stochastic-batch
mechanics under study are identical).  Encoder-only => no decode shapes.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="bert-large",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=30522,
        layer_pattern="B",
        act="gelu",
        norm="layernorm",
        pos="learned",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="bert-large-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=503,
        layer_pattern="B",
        act="gelu",
        norm="layernorm",
        pos="learned",
        dtype="float32",
        remat=False,
    )
