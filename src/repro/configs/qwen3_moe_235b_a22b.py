"""qwen3-moe-235b-a22b — MoE, 128 experts top-8  [hf:Qwen/Qwen3-30B-A3B family].

94L d_model=4096 64H (GQA kv=4, head_dim=128, QK-norm) per-expert
d_ff=1536 vocab=151936.  Full attention only => long_500k skipped.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        vocab_size=151_936,
        layer_pattern="G",
        use_qk_norm=True,
        n_experts=128,
        top_k=8,
        moe_d_ff=1536,
        capacity_factor=1.25,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        # >100B: pure-bf16 parameter storage (paired with bf16 Adam moments)
        # so every FSDP gather moves bf16 — see EXPERIMENTS.md §Perf.
        param_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=64,
        vocab_size=503,
        layer_pattern="G",
        use_qk_norm=True,
        n_experts=4,
        top_k=2,
        moe_d_ff=64,
        capacity_factor=2.0,
        tie_embeddings=False,
        dtype="float32",
        remat=False,
    )
