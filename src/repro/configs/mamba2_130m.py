"""mamba2-130m — SSD (state-space duality)  [arXiv:2405.21060].

24L d_model=768, attention-free (d_ff=0), vocab=50280, ssm_state=128.
Pure-SSM: runs all four shapes including long_500k (O(1) decode state).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        layer_pattern="M",
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
        ssm_conv=4,
        norm="rmsnorm",
        tie_embeddings=True,
        pos="none",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=503,
        layer_pattern="M",
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=32,
        ssm_chunk=16,
        pos="none",
        dtype="float32",
        remat=False,
    )
