"""mixtral-8x22b — MoE, 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
Per the assignment, SWA on all layers => qualifies for long_500k
(window-bounded KV cache).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        layer_pattern="L",
        sliding_window=4096,
        n_experts=8,
        top_k=2,
        moe_d_ff=16384,
        capacity_factor=1.25,
        act="swiglu",
        norm="rmsnorm",
        tie_embeddings=False,
        # >100B: pure-bf16 parameter storage (paired with bf16 Adam moments)
        # so every FSDP gather moves bf16 — see EXPERIMENTS.md §Perf.
        param_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=503,
        layer_pattern="L",
        sliding_window=16,
        n_experts=4,
        top_k=2,
        moe_d_ff=256,
        capacity_factor=2.0,
        tie_embeddings=False,
        dtype="float32",
        remat=False,
    )
