"""internvl2-1b — VLM: InternViT vision encoder + InternLM2 LM
[arXiv:2404.16821].

LM backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
Per the assignment the ViT+projector frontend is a STUB — ``input_specs``
provides 256 precomputed patch embeddings of width d_model.
Full attention only => long_500k skipped.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151_655,
        layer_pattern="G",
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        prefix_len=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=503,
        layer_pattern="G",
        prefix_len=8,
        dtype="float32",
        remat=False,
    )
