"""gemma3-27b — dense GQA, 5 local : 1 global layers, 128k context
[hf:google/gemma-3-1b-pt family].

62L d_model=5376 32H (GQA kv=16, head_dim=128) d_ff=21504 vocab=262144.
Sliding-window (1024) on local layers => runs long_500k: local caches are
window-bounded; the ~10 global layers keep full-length caches, sharded
along the sequence axis.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262_144,
        layer_pattern="LLLLLG",
        sliding_window=1024,
        use_qk_norm=True,
        logit_softcap=0.0,
        act="geglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        family="dense",
        n_layers=7,  # 6-layer unit + 1 tail layer: exercises grouped scan
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=503,
        layer_pattern="LLLLLG",
        sliding_window=16,
        use_qk_norm=True,
        act="geglu",
        dtype="float32",
        remat=False,
    )
