"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2
[arXiv:2402.19427].

26L d_model=2560 10H (GQA kv=1 = MQA) d_ff=7680 vocab=256000.
Pattern unit RRL: two recurrent blocks per local-attention block.
Hybrid recurrence => runs long_500k (O(1) recurrent state + 2k window).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        layer_pattern="RRL",
        sliding_window=2048,
        rglru_expand=1.5,
        rglru_conv=4,
        act="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        n_layers=3,
        d_model=128,
        n_heads=2,
        n_kv_heads=1,
        head_dim=64,
        d_ff=256,
        vocab_size=503,
        layer_pattern="RRL",
        sliding_window=16,
        act="geglu",
        dtype="float32",
        remat=False,
    )
