"""BERT-1.5B — the paper's §5.2 runtime model  [Habana 2023 DeepSpeed blog].

48L d_model=1600 25H d_ff=6400 vocab=30522 (~1.5B params), trained with
LANS + ZeRO-1, local batch 192, 12 accumulations, seq 128 — the exact
setting of the paper's runtime experiments (appendix B.1).
Encoder-only => no decode shapes.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="bert-1.5b",
        family="dense",
        n_layers=48,
        d_model=1600,
        n_heads=25,
        n_kv_heads=25,
        d_ff=6400,
        vocab_size=30522,
        layer_pattern="B",
        act="gelu",
        norm="layernorm",
        pos="learned",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="bert-1.5b-smoke",
        family="dense",
        n_layers=2,
        d_model=160,
        n_heads=5,
        n_kv_heads=5,
        d_ff=320,
        vocab_size=503,
        layer_pattern="B",
        act="gelu",
        norm="layernorm",
        pos="learned",
        dtype="float32",
        remat=False,
    )
