"""whisper-tiny — encoder-decoder audio model  [arXiv:2212.04356].

4L (enc) + 4L (dec) d_model=384 6H (kv=6, MHA) d_ff=1536 vocab=51865.
Per the assignment the mel-spectrogram + conv frontend is a STUB —
``input_specs`` provides 1500 precomputed frame embeddings.
Decode shapes run (autoregressive decoder w/ self+cross KV caches);
long_500k skipped (full-attention decoder).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,
        enc_layers=4,
        enc_seq=1500,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        layer_pattern="G",
        act="gelu",
        norm="layernorm",
        pos="learned",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2,
        enc_layers=2,
        enc_seq=16,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=503,
        layer_pattern="G",
        act="gelu",
        norm="layernorm",
        pos="learned",
        dtype="float32",
        remat=False,
    )
