"""mamba2-tiny — CPU-sized pure-SSD config for the serving parity matrix.

A two-layer 'M' pattern small enough that the chunked-prefill /
token-packed / decode-oracle parity suite runs in seconds on CPU, with a
chunk size (``ssm_chunk=8``) small enough that realistic prompts span
several scan chunks — the case the carried-state chunk scan
(``kernels.ssd_chunk`` + ``models.recurrent``) must get right.

Not in ``ARCHITECTURES`` (``mamba2_130m`` is the published architecture);
tests and benchmarks import it directly via ``get_config("mamba2_tiny")``.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-tiny",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=211,
        layer_pattern="M",
        ssm_state=8,
        ssm_expand=2,
        ssm_head_dim=16,
        ssm_chunk=8,
        pos="none",
        dtype="float32",
        remat=False,
    )


def smoke_config() -> ModelConfig:
    return config()
