"""Architecture registry: the 10 assigned configs + the paper's own models.

Each module exposes ``config()`` (the exact published architecture) and
``smoke_config()`` (a reduced same-family variant: <=2-3 layers,
d_model<=512, <=4 experts) used by the per-arch CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import List

ARCHITECTURES: List[str] = [
    "mamba2_130m",
    "internlm2_1_8b",
    "recurrentgemma_2b",
    "qwen2_5_3b",
    "mixtral_8x22b",
    "internvl2_1b",
    "starcoder2_7b",
    "qwen3_moe_235b_a22b",
    "gemma3_27b",
    "whisper_tiny",
]

# The paper's own models (DropCompute §5: BERT-Large + BERT-1.5B)
PAPER_MODELS: List[str] = ["bert_large", "bert_1_5b"]


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str):
    return importlib.import_module(f"repro.configs.{_norm(name)}").config()


def get_smoke_config(name: str):
    return importlib.import_module(f"repro.configs.{_norm(name)}").smoke_config()


def all_configs():
    return {a: get_config(a) for a in ARCHITECTURES}
