"""internlm2-1.8b — dense GQA  [arXiv:2403.17297].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
Full attention only => long_500k skipped (see DESIGN.md §long-context).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        layer_pattern="G",
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=503,
        layer_pattern="G",
        tie_embeddings=False,
        dtype="float32",
        remat=False,
    )
