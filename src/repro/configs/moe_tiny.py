"""moe-tiny — CPU-sized mixture-of-experts config for the serving parity matrix.

Small enough that the full engine parity suite (dense / packed / paged x
token-budget x capacity-factor dispatch) runs in seconds on CPU, while
still exercising the MoE-specific machinery: the router, top-k dispatch,
and the capacity-factor serving path (``models.moe.apply_moe_capacity``)
whose per-expert buffers are the serving analogue of the paper's
per-worker compute threshold tau.

Not in ``ARCHITECTURES`` (it reproduces no published model); tests and
benchmarks import it directly via ``get_config("moe_tiny")``.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moe-tiny",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=211,
        layer_pattern="G",
        n_experts=4,
        top_k=2,
        moe_d_ff=64,
        dtype="float32",
        remat=False,
    )


def smoke_config() -> ModelConfig:
    return config()
