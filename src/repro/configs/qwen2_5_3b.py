"""qwen2.5-3b — dense GQA with QKV bias  [hf:Qwen/Qwen2.5-0.5B family].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
Full attention only => long_500k skipped.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        d_ff=11008,
        vocab_size=151_936,
        layer_pattern="G",
        qkv_bias=True,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=503,
        layer_pattern="G",
        qkv_bias=True,
        dtype="float32",
        remat=False,
    )
