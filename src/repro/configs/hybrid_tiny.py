"""hybrid-tiny — CPU-sized RG-LRU + attention hybrid for the parity matrix.

A griffin-style interleaving ('RRG' repeated) small enough for the CPU
parity suite: the engine must thread *heterogeneous* per-layer state —
slot-indexed recurrent rows beside (dense or paged) attention KV — through
one step program, which is exactly the LayerState protocol
(``serve.kv.KVState``) this config exists to exercise.

Not in ``ARCHITECTURES`` (``recurrentgemma_2b`` is the published
architecture); tests and benchmarks import it directly via
``get_config("hybrid_tiny")``.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hybrid-tiny",
        family="recurrent",
        n_layers=3,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=211,
        layer_pattern="RRG",
        rglru_expand=1.0,
        rglru_conv=4,
        dtype="float32",
        remat=False,
    )


def smoke_config() -> ModelConfig:
    return config()
