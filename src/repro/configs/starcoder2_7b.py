"""starcoder2-7b — dense GQA with RoPE  [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
StarCoder2 uses LayerNorm + GELU MLP (non-gated).  Full attention only
=> long_500k skipped.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        layer_pattern="G",
        qkv_bias=True,
        act="gelu",
        norm="layernorm",
        rope_theta=100_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke",
        family="dense",
        n_layers=2,
        d_model=144,
        n_heads=4,
        n_kv_heads=2,
        d_ff=288,
        vocab_size=503,
        layer_pattern="G",
        qkv_bias=True,
        act="gelu",
        norm="layernorm",
        dtype="float32",
        remat=False,
    )
