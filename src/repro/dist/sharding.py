"""Path-based sharding rules: one table for params, optimizer state, caches.

Every parameter leaf is addressed by its tree path (``stack/groups/0/attn/
wq``) and matched against a table of *logical* rules keyed by the trailing
path segments (``attn/wq``).  Rules are written for the un-stacked layer
shape and **right-aligned** against the actual leaf rank, so the scanned
variants (``groups/<i>/...`` with a leading n_groups dim) automatically
get the same spec plus a leading ``None`` — and optimizer moments, whose
paths are the parameter paths under a ``m``/``v``/``mu`` prefix, match the
same suffixes for free (ZeRO sharding falls out of the table).

Logical axes:

* ``FSDP = ("data",)`` — parameter/optimizer storage is sharded over the
  data axis (ZeRO-3); "pod" is deliberately excluded: the pod axis is the
  pure-DP DropCompute All-Reduce domain, params are replicated across it.
* ``"model"`` — tensor parallelism, matching the activation layout that
  ``transformer.constrain_activations`` pins (d_model on "model").

``_fit_spec`` is the legality pass: any mesh axis (or axis group) that
does not evenly divide its dimension is dropped (outermost first, so
("pod", "data") degrades to ("data",) before giving up), and an axis is
never used twice in one spec.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import axes_size, dp_axes

PyTree = Any

FSDP: Tuple[str, ...] = ("data",)

# (path suffix, logical axes for the un-stacked shape), first match wins.
# Leaves with no matching rule are replicated.
RULES: Tuple[Tuple[str, Tuple], ...] = (
    # attention projections: (d, h, hd) / (h, hd, d)
    ("attn/wq", (FSDP, "model", None)),
    ("attn/wk", (FSDP, "model", None)),
    ("attn/wv", (FSDP, "model", None)),
    ("attn/wo", ("model", None, FSDP)),
    ("attn/bq", ("model", None)),
    ("attn/bk", ("model", None)),
    ("attn/bv", ("model", None)),
    ("cross_attn/wq", (FSDP, "model", None)),
    ("cross_attn/wk", (FSDP, "model", None)),
    ("cross_attn/wv", (FSDP, "model", None)),
    ("cross_attn/wo", ("model", None, FSDP)),
    # dense MLP: (d, f) / (f, d)
    ("mlp/w_in", (FSDP, "model")),
    ("mlp/w_gate", (FSDP, "model")),
    ("mlp/w_out", ("model", FSDP)),
    # MoE router: (d, e) — d-sharded like apply_moe_spmd's in_specs
    ("moe/router", ("model", None)),
    # embeddings: (V, d) / (d, V)
    ("embed/embedding", (FSDP, "model")),
    ("embed/unembed", (FSDP, "model")),
    ("embed/pos_embedding", (None, "model")),
    ("encoder/pos_embedding", (None, "model")),
    # RG-LRU (recurrentgemma): (d, dr) / (dr, d) / per-channel vectors
    ("rglru/w_branch", (FSDP, "model")),
    ("rglru/w_gate_branch", (FSDP, "model")),
    ("rglru/w_out", ("model", FSDP)),
    ("rglru/conv_w", (None, "model")),
    ("rglru/conv_b", ("model",)),
    ("rglru/gate_a_w", ("model",)),
    ("rglru/gate_a_b", ("model",)),
    ("rglru/gate_x_w", ("model",)),
    ("rglru/gate_x_b", ("model",)),
    ("rglru/lam", ("model",)),
    # Mamba-2 SSD: (d, proj) / (di, d) / per-channel vectors
    ("ssd/w_in", (FSDP, "model")),
    ("ssd/w_out", ("model", FSDP)),
    ("ssd/conv_w", (None, "model")),
    ("ssd/conv_b", ("model",)),
    ("ssd/norm_scale", ("model",)),
)


def _moe_expert_axes(leaf: str, shape: Sequence[int]) -> Optional[Tuple]:
    """Expert-TP factorization, shape-selected to mirror ``apply_moe_spmd``:

    d_psum (f < d, qwen3-like): contract the d-slice, psum — shard d;
    ag_f   (f >= d, mixtral-like): f-sharded experts — shard f.
    In both, the model axis lands on the *larger* of the two trailing dims;
    the expert dim is FSDP storage.
    """
    if len(shape) < 3:
        return None
    if leaf in ("w_in", "w_gate"):  # (e, d, f)
        d, f = shape[-2], shape[-1]
        return (FSDP, None, "model") if f >= d else (FSDP, "model", None)
    if leaf == "w_out":  # (e, f, d)
        f, d = shape[-2], shape[-1]
        return (FSDP, "model", None) if f >= d else (FSDP, None, "model")
    return None


def _logical_axes(segs: Sequence[str], shape: Sequence[int]) -> Optional[Tuple]:
    if len(segs) >= 2 and segs[-2] == "moe":
        axes = _moe_expert_axes(segs[-1], shape)
        if axes is not None:
            return axes
    for key, axes in RULES:
        ks = key.split("/")
        if len(segs) >= len(ks) and list(segs[-len(ks):]) == ks:
            return axes
    return None


def _fit_spec(shape: Sequence[int], axes: Sequence, mesh) -> P:
    """Drop mesh axes that don't divide their dim (outermost first) or were
    already used by an earlier dim; single-name entries keep their form."""
    used: set = set()
    out = []
    for dim, entry in zip(shape, axes):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        names = tuple(n for n in names if n in mesh.shape and n not in used)
        while names and dim % axes_size(mesh, names) != 0:
            names = names[1:]
        if not names:
            out.append(None)
        elif isinstance(entry, str):
            out.append(names[0])
            used.add(names[0])
        else:
            out.append(names)
            used.update(names)
    return P(*out)


def spec_for_path(path: str, shape: Sequence[int], mesh) -> P:
    """PartitionSpec for one leaf, from its tree path and shape.

    The rule's logical axes are right-aligned against ``shape`` (leading
    dims get ``None`` — covers scanned/stacked ``groups/<i>/...`` leaves)
    and then legality-fitted to the mesh by ``_fit_spec``.
    """
    segs = [s for s in str(path).split("/") if s]
    axes = _logical_axes(segs, shape)
    if axes is None:
        return P()
    axes = tuple(axes)
    if len(axes) >= len(shape):
        axes = axes[len(axes) - len(shape):]
    else:
        axes = (None,) * (len(shape) - len(axes)) + axes
    return _fit_spec(shape, axes, mesh)


# ---------------------------------------------------------------------------
# Tree-level shardings
# ---------------------------------------------------------------------------


def _path_str(key_path) -> str:
    segs = []
    for k in key_path:
        if hasattr(k, "key"):
            segs.append(str(k.key))
        elif hasattr(k, "idx"):
            segs.append(str(k.idx))
        elif hasattr(k, "name"):
            segs.append(str(k.name))
        else:
            segs.append(str(k))
    return "/".join(segs)


def tree_shardings(tree: PyTree, mesh) -> PyTree:
    """NamedSharding for every leaf of ``tree`` via ``spec_for_path``.

    Works on concrete arrays and ``ShapeDtypeStruct`` trees alike; leaves
    with no matching path rule come out replicated.
    """
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: NamedSharding(mesh, spec_for_path(_path_str(kp), x.shape, mesh)),
        tree,
    )


def param_shardings(params: PyTree, mesh) -> PyTree:
    return tree_shardings(params, mesh)


def opt_shardings(opt_state: PyTree, mesh) -> PyTree:
    """Optimizer-state shardings (ZeRO): moment trees mirror the parameter
    paths under a ``m``/``v``/``mu`` prefix, so the same suffix rules apply;
    scalar counters fall through to replicated."""
    return tree_shardings(opt_state, mesh)


def cache_shardings(cache: PyTree, mesh, shard_seq: bool = False) -> PyTree:
    """Decode-cache shardings.

    Default: batch (dim 0) over the data axes, heads/channels over "model"
    (KV leaves ``k``/``v`` are (B, S, kv_heads, hd): "model" lands on the
    head dim; recurrent/conv states get "model" on their channel dim).

    ``shard_seq=True``: shard the KV *sequence* dim over "data" instead of
    batch — for long-context decode where global_batch < dp_size (e.g.
    long_500k's single sequence on the production mesh).

    Accepts the legacy cache dict or a ``repro.serve.kv.KVState`` (the
    result mirrors the input container).  For the *paged* layout the KV
    leaves are ``(num_pages, page_size, kv_heads, hd)`` pools: the page
    dim takes the data axes (the pool is the batch-like dim now), heads
    take "model", and the block tables — a few KiB of int32 indices every
    device needs for its gathers — stay replicated.  Serving a paged
    cache under a Distribution additionally needs the mesh-aware page
    gather (``ContinuousBatcher`` raises ``UnsupportedDistError`` until
    the multi-host serving mesh lands); these placements are what that
    path will consume.
    """
    dp = dp_axes(mesh)
    tables = getattr(cache, "tables", None)
    data = getattr(cache, "data", cache)
    paged = tables is not None

    def leaf(kp, x):
        name = _path_str(kp).rsplit("/", 1)[-1]
        nd = len(x.shape)
        if paged and name in ("k", "v") and nd >= 4:
            # (…, num_pages, page_size, kv_heads, hd); group-scanned
            # leaves carry a leading n_groups dim — right-align.
            axes = (None,) * (nd - 4) + (dp, None, "model", None)
        elif paged and name in ("k_scale", "v_scale") and nd >= 3:
            # int8 dequant scales (…, num_pages, page_size, kv_heads):
            # co-placed with their pools — pages over data, heads over
            # "model" — so the kernel's per-page loads stay local.
            axes = (None,) * (nd - 3) + (dp, None, "model")
        elif name in ("k", "v") and nd == 4:
            axes = (None, ("data",), "model", None) if shard_seq else (dp, None, "model", None)
        elif name == "state" and nd >= 4:
            # SSD recurrent state (…, num_slots, n_heads, N, head_p):
            # slot-indexed in both layouts (never paged) — slots over the
            # data axes, heads over "model" like attention KV.
            axes = (None,) * (nd - 4) + (dp, "model", None, None)
        elif name == "h" and nd >= 2:
            # RG-LRU hidden state (…, num_slots, d_r): channels on "model".
            axes = (None,) * (nd - 2) + (dp, "model")
        elif name == "conv" and nd >= 3:
            # conv windows (…, num_slots, K-1, channels), rglru and ssd.
            axes = (None,) * (nd - 3) + (dp, None, "model")
        elif nd >= 2:
            axes = (dp,) + (None,) * (nd - 2) + ("model",)
        else:
            axes = (dp,)
        return NamedSharding(mesh, _fit_spec(x.shape, axes, mesh))

    data_sh = jax.tree_util.tree_map_with_path(leaf, data)
    if hasattr(cache, "data"):
        return dataclasses.replace(
            cache,
            data=data_sh,
            tables=NamedSharding(mesh, P()) if paged else None,
        )
    return data_sh


def batch_spec(mesh, global_batch: int) -> P:
    """Leading-dim spec for the global batch: over ("pod", "data") when the
    pod axis exists, degrading outermost-first until it divides."""
    dp = dp_axes(mesh)
    while dp and global_batch % axes_size(mesh, dp) != 0:
        dp = dp[1:]
    return P(dp if dp else None)
