"""``Distribution`` — the one object the rest of the codebase talks to.

A ``Distribution`` bundles a mesh with the path-based sharding rules and
the donation policy, and exposes the only supported way to build SPMD
steps: ``train_step`` / ``prefill_step`` / ``serve_step`` return a
``StepBundle`` whose function is jitted with the right in/out shardings
and donated buffers, plus the abstract inputs needed to ``lower()`` it
without allocating anything (the dry-run path).

    dist = Distribution.for_devices()                  # dev mesh
    dist = Distribution.production(multi_pod=True)     # 2x16x16 pods
    dist = Distribution.from_spec("4,2")               # --mesh CLI flag

    bundle = dist.train_step(cfg, shape, drop)
    params, opt_state, metrics = bundle(params, opt_state, batch, lat)
    lowered = bundle.lower()                           # dry-run / HLO

Callers that only need placements use ``param_shardings`` /
``opt_shardings`` / ``cache_shardings`` / ``batch_shardings`` — thin,
mesh-bound views over ``repro.dist.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from . import mesh as mesh_lib
from . import sharding as rules

PyTree = Any


@dataclasses.dataclass
class StepBundle:
    """A jitted SPMD step plus everything needed to run or lower it."""

    fn: Callable  # jitted; call under ``with bundle`` or directly
    mesh: Any
    abstract_inputs: Tuple  # ShapeDtypeStructs accepted by ``fn``
    in_shardings: Tuple
    out_shardings: Any
    opt: Any = None  # train steps carry their optimizer

    def __call__(self, *args):
        with self.mesh:
            return self.fn(*args)

    def lower(self):
        with self.mesh:
            return self.fn.lower(*self.abstract_inputs)


@dataclasses.dataclass(frozen=True)
class Distribution:
    """Mesh + sharding rules + donation policy, as one value."""

    mesh: Any
    donate: bool = True

    # -- constructors -------------------------------------------------------

    @classmethod
    def for_devices(
        cls, n_devices: Optional[int] = None, model_parallel: int = 1, **kw
    ) -> "Distribution":
        return cls(mesh_lib.make_dev_mesh(n_devices, model_parallel), **kw)

    @classmethod
    def production(cls, multi_pod: bool = False, **kw) -> "Distribution":
        return cls(mesh_lib.make_production_mesh(multi_pod=multi_pod), **kw)

    @classmethod
    def from_spec(cls, spec: Union[str, Tuple[int, ...]], **kw) -> "Distribution":
        """Parse a ``--mesh`` flag: "4,2" -> (data=4, model=2); "2,16,16"
        -> (pod, data, model)."""
        dims = tuple(int(x) for x in spec.split(",")) if isinstance(spec, str) else tuple(spec)
        names = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}
        if len(dims) not in names:
            raise ValueError(f"--mesh wants 1-3 comma-separated dims, got {spec!r}")
        return cls(mesh_lib.make_mesh(dims, names[len(dims)]), **kw)

    # -- topology -----------------------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

    @property
    def dp_size(self) -> int:
        """Data parallelism == the DropCompute worker count W."""
        return mesh_lib.dp_size(self.mesh)

    @property
    def tp_size(self) -> int:
        return mesh_lib.tp_size(self.mesh)

    # -- placements ---------------------------------------------------------

    def spec_for_path(self, path: str, shape) -> P:
        return rules.spec_for_path(path, shape, self.mesh)

    def param_shardings(self, params: PyTree) -> PyTree:
        return rules.param_shardings(params, self.mesh)

    def opt_shardings(self, opt_state: PyTree) -> PyTree:
        return rules.opt_shardings(opt_state, self.mesh)

    def cache_shardings(self, cache: PyTree, shard_seq: bool = False) -> PyTree:
        return rules.cache_shardings(cache, self.mesh, shard_seq=shard_seq)

    def batch_shardings(self, cfg, shape) -> PyTree:
        from ..launch import steps as S  # lazy: steps imports repro.dist

        return S.batch_shardings(cfg, shape, self.mesh)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def shard(self, tree: PyTree, shardings: Optional[PyTree] = None) -> PyTree:
        """Place a concrete pytree onto the mesh (params by default)."""
        if shardings is None:
            shardings = self.param_shardings(tree)
        return jax.device_put(tree, shardings)

    # -- step builders (the single entry point for SPMD programs) ----------

    def train_step(self, cfg, shape, drop, **kw) -> StepBundle:
        """Jitted DropCompute train step, sharded by the rules.

        ``kw`` forwards to ``launch.steps.make_train_step`` (optimizer, lr,
        clip_norm, moe_impl, state_dtype, accum_dtype, cast_params_once,
        weight_decay).  ``n_workers`` defaults to the mesh's dp size.
        """
        from ..launch import steps as S

        n_workers = kw.pop("n_workers", None) or self.dp_size
        opt, step = S.make_train_step(cfg, shape, drop, n_workers, **kw)
        params_abs = S.abstract_params(cfg)
        opt_abs = S.abstract_opt_state(cfg, opt, params_abs)
        specs = S.input_specs(cfg, shape, self.mesh, n_workers=n_workers)
        b_sh = S.batch_shardings(cfg, shape, self.mesh, n_workers=n_workers)
        p_sh = self.param_shardings(params_abs)
        o_sh = self.opt_shardings(opt_abs)
        in_sh = (p_sh, o_sh, b_sh["batch"], b_sh["latencies"])
        out_sh = (p_sh, o_sh, None)
        jitted = jax.jit(
            step,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=(0, 1) if self.donate else (),
        )
        return StepBundle(
            fn=jitted,
            mesh=self.mesh,
            abstract_inputs=(params_abs, opt_abs, specs["batch"], specs["latencies"]),
            in_shardings=in_sh,
            out_shardings=out_sh,
            opt=opt,
        )

    def prefill_step(self, cfg, shape, **kw) -> StepBundle:
        from ..launch import steps as S

        step = S.make_prefill_step(cfg, **kw)
        params_abs = S.abstract_params(cfg)
        specs = S.input_specs(cfg, shape, self.mesh)
        b_sh = S.batch_shardings(cfg, shape, self.mesh)
        p_sh = self.param_shardings(params_abs)
        in_sh = (p_sh, b_sh["batch"])
        jitted = jax.jit(step, in_shardings=in_sh)
        return StepBundle(
            fn=jitted,
            mesh=self.mesh,
            abstract_inputs=(params_abs, specs["batch"]),
            in_shardings=in_sh,
            out_shardings=None,
        )

    def serve_step(self, cfg, shape, shard_seq: Optional[bool] = None, **kw) -> StepBundle:
        from ..launch import steps as S

        step = S.make_serve_step(cfg, **kw)
        params_abs = S.abstract_params(cfg)
        cache_abs = S.abstract_cache(cfg, shape)
        specs = S.input_specs(cfg, shape, self.mesh)
        b_sh = S.batch_shardings(cfg, shape, self.mesh)
        if shard_seq is None:
            shard_seq = shape.global_batch < self.dp_size
        p_sh = self.param_shardings(params_abs)
        c_sh = self.cache_shardings(cache_abs, shard_seq=shard_seq)
        in_sh = (p_sh, c_sh, b_sh["token"], b_sh["pos"])
        out_sh = (None, c_sh)
        jitted = jax.jit(
            step,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=(1,) if self.donate else (),
        )
        return StepBundle(
            fn=jitted,
            mesh=self.mesh,
            abstract_inputs=(params_abs, cache_abs, specs["token"], specs["pos"]),
            in_shardings=in_sh,
            out_shardings=out_sh,
        )
