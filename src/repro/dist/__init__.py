"""repro.dist — the distribution API for the whole codebase.

* ``repro.dist.mesh``     — mesh construction + jax 0.4/0.5 compat seam.
* ``repro.dist.sharding`` — path-based sharding rules (params, opt state,
  decode caches, batches).
* ``repro.dist.api``      — ``Distribution``: mesh + rules + donation, and
  the single entry point for building sharded train/prefill/serve steps.
"""
from .api import Distribution, StepBundle
from .mesh import (
    HW,
    dp_axes,
    dp_size,
    make_dev_mesh,
    make_mesh,
    make_production_mesh,
    tp_size,
)
from .sharding import (
    batch_spec,
    cache_shardings,
    opt_shardings,
    param_shardings,
    spec_for_path,
)

__all__ = [
    "Distribution",
    "StepBundle",
    "HW",
    "dp_axes",
    "dp_size",
    "tp_size",
    "make_dev_mesh",
    "make_mesh",
    "make_production_mesh",
    "batch_spec",
    "cache_shardings",
    "opt_shardings",
    "param_shardings",
    "spec_for_path",
]
