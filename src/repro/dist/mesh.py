"""Mesh construction for every layer of the stack (the one place it happens).

Production target (TPU v5e):

    single pod:  16 x 16 = 256 chips, axes (data, model)
    multi-pod:   2 x 16 x 16 = 512 chips, axes (pod, data, model) — pure DP
                 across "pod" (the DropCompute All-Reduce domain spans pods).

Everything here is a function, never a module-level constant: importing
this module must not touch jax device state (the dry-run sets XLA_FLAGS
before the first backend init).

``make_mesh`` is the jax-0.4/0.5 compat seam: jax >= 0.5 grew
``jax.sharding.AxisType`` and an ``axis_types=`` kwarg on
``jax.make_mesh``; on 0.4.x neither exists and every axis is implicitly
Auto.  Callers (tests included) go through this helper so the same code
runs on both.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (AxisType.Auto,) * n}`` on jax >= 0.5, ``{}`` on 0.4."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(
    axis_shapes: Sequence[int], axis_names: Sequence[str], *, devices=None
):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    kwargs = axis_types_kwargs(len(axis_names))
    if devices is not None:
        kwargs["devices"] = devices
    try:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    except TypeError:
        # jax builds where make_mesh predates axis_types / devices kwargs
        kwargs.pop("axis_types", None)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_dev_mesh(n_devices: Optional[int] = None, model_parallel: int = 1):
    """Small (data, model) mesh over whatever devices exist (CPU / laptops)."""
    n = n_devices or len(jax.devices())
    assert n % model_parallel == 0, (n, model_parallel)
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))


# ---------------------------------------------------------------------------
# Axis arithmetic shared by the sharding rules and the step builders
# ---------------------------------------------------------------------------

DATA_AXES: Tuple[str, ...] = ("pod", "data")  # batch is sharded over these


def axes_size(mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def dp_axes(mesh) -> Tuple[str, ...]:
    """The mesh's data-parallel axes, outermost first."""
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def dp_size(mesh) -> int:
    """Total data parallelism (the DropCompute worker count W)."""
    return axes_size(mesh, DATA_AXES)


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
HW = {
    "name": "tpu_v5e",
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bandwidth": 819e9,  # B/s
    "ici_link_bandwidth": 50e9,  # B/s per link
}
