"""Composable model definitions for all assigned architecture families."""
from .config import INPUT_SHAPES, InputShape, ModelConfig
from .model import (
    decode_step,
    encode,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
    per_token_losses,
)

__all__ = [
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "decode_step",
    "encode",
    "forward",
    "init_decode_cache",
    "init_params",
    "loss_fn",
    "per_token_losses",
]
