"""RG-LRU recurrent block (Griffin / RecurrentGemma)  [arXiv:2402.19427].

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(w_a * x_t + b_a)          (recurrence gate)
    i_t = sigmoid(w_x * x_t + b_x)          (input gate)
    a_t = a ** (c * r_t),  a = sigmoid(lam) (per-channel decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: the linear recurrence is evaluated with
``jax.lax.associative_scan`` (log-depth, maps well to TPU vector units)
instead of a GPU-style sequential kernel.  Gates use per-channel (diagonal)
weights — Griffin's block-diagonal gate matrices reduced to their diagonal;
noted in DESIGN.md §Hardware-adaptation.

Decode carries (h, conv_state) => O(1) per token, which is what lets the
hybrid recurrentgemma run ``long_500k``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init
from .recurrent import chunked_conv_state, packed_conv, segment_info

_C = 8.0


def init_rglru(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    dr = int(cfg.rglru_expand * d)
    ks = jax.random.split(rng, 4)
    return {
        "w_branch": dense_init(ks[0], (d, dr), dtype=cfg.params_dtype),
        "w_gate_branch": dense_init(ks[1], (d, dr), dtype=cfg.params_dtype),
        "conv_w": dense_init(ks[2], (cfg.rglru_conv, dr), in_axis=0, dtype=cfg.params_dtype),
        "conv_b": jnp.zeros((dr,), cfg.params_dtype),
        "gate_a_w": jnp.zeros((dr,), cfg.params_dtype),
        "gate_a_b": jnp.zeros((dr,), cfg.params_dtype),
        "gate_x_w": jnp.zeros((dr,), cfg.params_dtype),
        "gate_x_b": jnp.zeros((dr,), cfg.params_dtype),
        # lambda init so that a = sigmoid(lam) spans (0.9, 0.999)
        "lam": jnp.linspace(2.2, 6.9, dr).astype(cfg.params_dtype),
        "w_out": dense_init(ks[3], (dr, d), dtype=cfg.params_dtype),
    }


def _conv(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : xp.shape[1] - (k - 1) + i] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1) :]
    return out + b, new_state


def _decay_and_update(x, r, i, a_param):
    """Per-step decay a_t and gated input sqrt(1-a_t^2)*(i*x), both fp32."""
    log_a = -_C * r * jax.nn.softplus(-a_param)  # log(a^(c r)), a=sigmoid(lam)
    a_t = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a_t**2, 1e-12)) * (i * x)
    return a_t, gated


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def _rglru_scan(x, r, i, a_param):
    """Linear recurrence via associative scan. x/r/i: (B, S, Dr) fp32.

    Returns ``(a_all, h)``: the running decay product and the recurrence
    output, both (B, S, Dr).  ``a_all`` is the factor a carried initial
    state picks up at each position — ``h_full = h + a_all * h0`` — which
    the stateful chunked-prefill path uses.
    """
    a_t, gated = _decay_and_update(x, r, i, a_param)
    a_all, h = jax.lax.associative_scan(_combine, (a_t, gated), axis=1)
    return a_all, h


def _gates(p, uf):
    r = jax.nn.sigmoid(uf * p["gate_a_w"].astype(jnp.float32) + p["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * p["gate_x_w"].astype(jnp.float32) + p["gate_x_b"].astype(jnp.float32))
    return r, i


def apply_rglru(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    seq_lens: Optional[jnp.ndarray] = None,
    slot_ids: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """One RG-LRU block. x: (B, S, D).

    Cache selects the serving path (mirroring ``apply_ssd``): with
    ``seq_lens`` a dense chunked-prefill step — columns past a row's
    length get a_t=1, gated=0, an exact identity, so the final column's
    state IS the state after the row's last real token; with
    ``slot_ids`` a token-packed step — the carried h is injected at each
    segment's first token (whose a_t is re-routed into the injection and
    zeroed in the scan, cutting cross-segment flow) and written back from
    its last; with neither, single-token decode.
    """
    cd = cfg.compute_dtype
    u = jnp.einsum("bsd,de->bse", x, p["w_branch"].astype(cd))
    g = jnp.einsum("bsd,de->bse", x, p["w_gate_branch"].astype(cd))

    if cache is None:
        u, _ = _conv(u, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
        uf = u.astype(jnp.float32)
        r, i = _gates(p, uf)
        _, h = _rglru_scan(uf, r, i, p["lam"].astype(jnp.float32))
        new_cache = None
    elif seq_lens is not None:
        bs, s = u.shape[:2]
        k = cfg.rglru_conv
        u_c, _ = _conv(u, p["conv_w"].astype(cd), p["conv_b"].astype(cd), cache["conv"])
        xp = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
        conv_state = chunked_conv_state(xp, seq_lens, k).astype(cache["conv"].dtype)
        uf = u_c.astype(jnp.float32)
        r, i = _gates(p, uf)
        a_t, gated = _decay_and_update(uf, r, i, p["lam"].astype(jnp.float32))
        valid = (jnp.arange(s)[None, :] < seq_lens[:, None])[..., None]
        a_t = jnp.where(valid, a_t, 1.0)  # identity past each row's length
        gated = jnp.where(valid, gated, 0.0)
        a_all, h = jax.lax.associative_scan(_combine, (a_t, gated), axis=1)
        h = h + a_all * cache["h"][:, None]
        new_cache = {"conv": conv_state, "h": h[:, -1]}
    elif slot_ids is not None:
        num_slots = cache["h"].shape[0]
        info = segment_info(slot_ids, num_slots)
        u_c, conv_state = packed_conv(
            u[0], p["conv_w"].astype(cd), p["conv_b"].astype(cd),
            cache["conv"], info,
        )
        uf = u_c.astype(jnp.float32)  # (P, Dr)
        r, i = _gates(p, uf)
        a_t, gated = _decay_and_update(uf, r, i, p["lam"].astype(jnp.float32))
        live = info.valid[:, None]
        h0 = cache["h"][info.safe_slot]  # (P, Dr)
        a_eff = jnp.where(info.start[:, None] | ~live, 0.0, a_t)
        b_eff = jnp.where(info.start[:, None], a_t * h0 + gated,
                          jnp.where(live, gated, 0.0))
        _, h = jax.lax.associative_scan(_combine, (a_eff, b_eff), axis=0)
        new_cache = {
            "conv": conv_state,
            "h": cache["h"].at[info.last_slot].set(h, mode="drop"),
        }
        h = h[None]
    else:
        u, conv_state = _conv(u, p["conv_w"].astype(cd), p["conv_b"].astype(cd), cache["conv"])
        uf = u.astype(jnp.float32)
        r = jax.nn.sigmoid(uf * p["gate_a_w"].astype(jnp.float32) + p["gate_a_b"].astype(jnp.float32))
        i = jax.nn.sigmoid(uf * p["gate_x_w"].astype(jnp.float32) + p["gate_x_b"].astype(jnp.float32))
        log_a = -_C * r * jax.nn.softplus(-p["lam"].astype(jnp.float32))
        a_t = jnp.exp(log_a)
        h = a_t * cache["h"][:, None] + jnp.sqrt(jnp.maximum(1.0 - a_t**2, 1e-12)) * (i * uf)
        new_cache = {"conv": conv_state, "h": h[:, 0]}

    y = h.astype(cd) * jax.nn.gelu(g)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd)), new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    dr = int(cfg.rglru_expand * cfg.d_model)
    return {
        "conv": jnp.zeros((batch, cfg.rglru_conv - 1, dr), cfg.compute_dtype),
        "h": jnp.zeros((batch, dr), jnp.float32),
    }
