"""RG-LRU recurrent block (Griffin / RecurrentGemma)  [arXiv:2402.19427].

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(w_a * x_t + b_a)          (recurrence gate)
    i_t = sigmoid(w_x * x_t + b_x)          (input gate)
    a_t = a ** (c * r_t),  a = sigmoid(lam) (per-channel decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: the linear recurrence is evaluated with
``jax.lax.associative_scan`` (log-depth, maps well to TPU vector units)
instead of a GPU-style sequential kernel.  Gates use per-channel (diagonal)
weights — Griffin's block-diagonal gate matrices reduced to their diagonal;
noted in DESIGN.md §Hardware-adaptation.

Decode carries (h, conv_state) => O(1) per token, which is what lets the
hybrid recurrentgemma run ``long_500k``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

_C = 8.0


def init_rglru(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    dr = int(cfg.rglru_expand * d)
    ks = jax.random.split(rng, 4)
    return {
        "w_branch": dense_init(ks[0], (d, dr), dtype=cfg.params_dtype),
        "w_gate_branch": dense_init(ks[1], (d, dr), dtype=cfg.params_dtype),
        "conv_w": dense_init(ks[2], (cfg.rglru_conv, dr), in_axis=0, dtype=cfg.params_dtype),
        "conv_b": jnp.zeros((dr,), cfg.params_dtype),
        "gate_a_w": jnp.zeros((dr,), cfg.params_dtype),
        "gate_a_b": jnp.zeros((dr,), cfg.params_dtype),
        "gate_x_w": jnp.zeros((dr,), cfg.params_dtype),
        "gate_x_b": jnp.zeros((dr,), cfg.params_dtype),
        # lambda init so that a = sigmoid(lam) spans (0.9, 0.999)
        "lam": jnp.linspace(2.2, 6.9, dr).astype(cfg.params_dtype),
        "w_out": dense_init(ks[3], (dr, d), dtype=cfg.params_dtype),
    }


def _conv(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : xp.shape[1] - (k - 1) + i] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1) :]
    return out + b, new_state


def _rglru_scan(x, r, i, a_param):
    """Linear recurrence via associative scan. x/r/i: (B, S, Dr) fp32."""
    log_a = -_C * r * jax.nn.softplus(-a_param)  # log(a^(c r)), a=sigmoid(lam)
    a_t = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a_t**2, 1e-12)) * (i * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_all, h = jax.lax.associative_scan(combine, (a_t, gated), axis=1)
    return h


def apply_rglru(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    cd = cfg.compute_dtype
    u = jnp.einsum("bsd,de->bse", x, p["w_branch"].astype(cd))
    g = jnp.einsum("bsd,de->bse", x, p["w_gate_branch"].astype(cd))

    if cache is None:
        u, _ = _conv(u, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
        uf = u.astype(jnp.float32)
        r = jax.nn.sigmoid(uf * p["gate_a_w"].astype(jnp.float32) + p["gate_a_b"].astype(jnp.float32))
        i = jax.nn.sigmoid(uf * p["gate_x_w"].astype(jnp.float32) + p["gate_x_b"].astype(jnp.float32))
        h = _rglru_scan(uf, r, i, p["lam"].astype(jnp.float32))
        new_cache = None
    else:
        u, conv_state = _conv(u, p["conv_w"].astype(cd), p["conv_b"].astype(cd), cache["conv"])
        uf = u.astype(jnp.float32)
        r = jax.nn.sigmoid(uf * p["gate_a_w"].astype(jnp.float32) + p["gate_a_b"].astype(jnp.float32))
        i = jax.nn.sigmoid(uf * p["gate_x_w"].astype(jnp.float32) + p["gate_x_b"].astype(jnp.float32))
        log_a = -_C * r * jax.nn.softplus(-p["lam"].astype(jnp.float32))
        a_t = jnp.exp(log_a)
        h = a_t * cache["h"][:, None] + jnp.sqrt(jnp.maximum(1.0 - a_t**2, 1e-12)) * (i * uf)
        new_cache = {"conv": conv_state, "h": h[:, 0]}

    y = h.astype(cd) * jax.nn.gelu(g)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd)), new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    dr = int(cfg.rglru_expand * cfg.d_model)
    return {
        "conv": jnp.zeros((batch, cfg.rglru_conv - 1, dr), cfg.compute_dtype),
        "h": jnp.zeros((batch, dr), jnp.float32),
    }
