"""Shared helpers for serving recurrent layers ('R'/'M') statefully.

Both recurrent blocks (``ssm.py``, ``rglru.py``) carry two kinds of
per-slot state through the engine: a causal-conv window (the last K-1
inputs) and the recurrence state itself.  This module holds the layout
machinery they share:

* dense chunked prefill — a ``(B, C)`` step where row ``i`` consumes
  ``seq_lens[i]`` tokens (0 for idle slots): the conv state window per
  row ends at its own length, not at C;
* token-packed steps — a ``(P,)`` vector of tokens with per-token slot
  ids (``serve.packing.PAD_SLOT`` on padding), segments contiguous: each
  token needs its segment-relative offset to know which conv taps come
  from the packed vector and which from the slot's carried window, and
  segment-start/segment-last flags gate carried-state injection and
  write-back.

JAX indexing caveat that shapes every scatter here: negative indices
WRAP (``a[-1]`` is the last row), so padding slot ids are remapped to an
out-of-range index (``num_slots``) and dropped with ``mode="drop"`` —
never scattered through raw.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SegmentInfo(NamedTuple):
    """Per-token segment geometry of one packed step (all shapes (P,))."""

    valid: jnp.ndarray  # bool: not padding
    start: jnp.ndarray  # bool: first token of its segment
    last: jnp.ndarray  # bool: last token of its segment
    start_idx: jnp.ndarray  # packed index of the segment's first token
    offset: jnp.ndarray  # segment-relative position (0 at segment start)
    safe_slot: jnp.ndarray  # slot id with padding clamped to 0 (gather-safe)
    write_slot: jnp.ndarray  # slot id with padding -> num_slots (scatter-drop)
    last_slot: jnp.ndarray  # slot id at seg-last tokens, else num_slots


def segment_info(slot_ids: jnp.ndarray, num_slots: int) -> SegmentInfo:
    """Derive segment flags/indices from a packed step's slot ids."""
    p = slot_ids.shape[0]
    idx = jnp.arange(p, dtype=jnp.int32)
    valid = slot_ids >= 0
    prev = jnp.concatenate([jnp.full((1,), -2, slot_ids.dtype), slot_ids[:-1]])
    nxt = jnp.concatenate([slot_ids[1:], jnp.full((1,), -2, slot_ids.dtype)])
    start = valid & (slot_ids != prev)
    last = valid & (slot_ids != nxt)
    start_idx = jax.lax.cummax(jnp.where(start, idx, -1))
    offset = idx - start_idx
    safe_slot = jnp.where(valid, slot_ids, 0)
    write_slot = jnp.where(valid, slot_ids, num_slots)
    last_slot = jnp.where(last, slot_ids, num_slots)
    return SegmentInfo(valid, start, last, start_idx, offset,
                       safe_slot, write_slot, last_slot)


def packed_conv(
    x: jnp.ndarray,  # (P, C) packed conv inputs
    w: jnp.ndarray,  # (K, C) depthwise taps
    b: jnp.ndarray,  # (C,) bias
    state: jnp.ndarray,  # (num_slots, K-1, C) carried windows
    info: SegmentInfo,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over a packed step with per-slot history.

    Tap ``i`` of token ``j`` reads segment-relative position
    ``offset_j - (K-1) + i``: non-negative positions come from the packed
    vector itself (same segment — segments are contiguous), negative ones
    from the slot's carried window.  Returns the pre-activation output
    (P, C) and the updated per-slot windows: each segment's last token
    scatters its trailing K-1 inputs; slots absent from this step keep
    their window untouched.
    """
    k = w.shape[0]
    p = x.shape[0]
    idx = jnp.arange(p, dtype=jnp.int32)
    win = state.astype(x.dtype)[info.safe_slot]  # (P, K-1, C)

    def tap(virt, src_tok):
        # virt: (P,) segment-relative position of the tap; < 0 => history
        tok_val = x[jnp.clip(src_tok, 0, p - 1)]
        st_idx = jnp.clip(virt + (k - 1), 0, k - 2)
        st_val = jnp.take_along_axis(win, st_idx[:, None, None], axis=1)[:, 0]
        return jnp.where((virt >= 0)[:, None], tok_val, st_val)

    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + w[i] * tap(info.offset - (k - 1) + i, idx - (k - 1) + i)
    out = out + b

    # the window ending at each token: its last K-1 inputs inclusive
    rows = [tap(info.offset - (k - 2) + m, idx - (k - 2) + m)
            for m in range(k - 1)]
    window = jnp.stack(rows, axis=1)  # (P, K-1, C)
    new_state = state.at[info.last_slot].set(
        window.astype(state.dtype), mode="drop"
    )
    return out, new_state


def chunked_conv_state(
    xp: jnp.ndarray,  # (B, K-1+C, C_feat): carried window ++ this chunk
    seq_lens: jnp.ndarray,  # (B,) tokens consumed per row this step
    k: int,
) -> jnp.ndarray:
    """Per-row conv windows after a dense chunked step, (B, K-1, C_feat).

    Row ``i``'s new window is the K-1 inputs ending at its own length —
    ``xp[i, L_i : L_i + K-1]`` — so an idle row (L_i = 0) keeps exactly
    its old window.
    """
    idx = seq_lens[:, None].astype(jnp.int32) + jnp.arange(k - 1, dtype=jnp.int32)
    return jnp.take_along_axis(xp, idx[:, :, None], axis=1)


def final_segment_decay(
    cum: jnp.ndarray,  # (P, H) cumulative log-decay over the packed axis
    da: jnp.ndarray,  # (P, H) per-token log-decay
    info: SegmentInfo,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decay bookkeeping for carried-state injection and write-back.

    Returns ``(ent, w_end)``, both (P, H):

    * ``ent[j]`` — log-decay from *before* the segment start through token
      ``j`` inclusive: ``cum_j - cum[start] + da[start]``.  The carried
      state's contribution at token j is ``exp(-ent_j)``; at the seg-last
      token it is the carried state's total decay over the segment.
    * ``w_end[j]`` — decay from token j (exclusive) to its segment's last
      token: ``exp(-(cum[end] - cum_j))`` — the weight of token j's state
      update in the segment-final state.
    """
    p = cum.shape[0]
    cum_start = cum[jnp.clip(info.start_idx, 0, p - 1)]
    da_start = da[jnp.clip(info.start_idx, 0, p - 1)]
    ent = cum - cum_start + da_start
    end_idx = jax.lax.cummin(
        jnp.where(info.last, jnp.arange(p, dtype=jnp.int32), p), reverse=True
    )
    cum_end = cum[jnp.clip(end_idx, 0, p - 1)]
    w_end = jnp.exp(-(cum_end - cum))
    return ent, w_end
