"""Block assembly: pattern-driven layer stacks with scan-over-groups.

The per-layer block sequence comes from ``cfg.pattern`` ('G'/'L'/'R'/'M',
see config.py).  Layers are organized as

    n_groups repetitions of the pattern unit   (params stacked, lax.scan)
  + a tail of (n_layers % unit) explicit layers (python loop)

so heterogeneous stacks (gemma3's 5 local :1 global, recurrentgemma's
2 recurrent : 1 local) still compile to a compact scanned HLO, while
homogeneous stacks degenerate to a plain scan over all layers.  The scan
body is rematerialized (``jax.checkpoint``) when cfg.remat.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import moe as MoE
from . import rglru as RG
from . import ssm as SSD

PyTree = Any


def constrain_activations(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Shard the residual stream (B, S, d) as (data-axes, None, model).

    Sharding d_model across the model axis keeps the per-layer remat
    residuals (stacked across the layer scan for backward) 16x smaller on
    the production mesh, at the cost of per-block gather/psum collectives
    around the projections.  Measured on gemma3-27b train_4k: WITH the
    constraint 4.7s compute / 33s collective / 9.8 GiB per device; WITHOUT
    it the partitioner loses its anchor inside the layer scan and produces
    8.2s / 62s / 31 GiB — so the constraint stays on for every model (the
    "skip it for small models" hypothesis was tested and refuted; see
    EXPERIMENTS.md §Perf).  No-op without an active multi-device mesh.
    """
    from .moe import _current_mesh  # lazy: avoids cycle

    mesh = _current_mesh()
    if mesh is None or mesh.devices.size == 1 or x.ndim != 3:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    while dp and x.shape[0] % n != 0:
        dp = dp[1:]
        n = 1
        for a in dp:
            n *= mesh.shape[a]
    tp = "model" if "model" in mesh.axis_names and x.shape[-1] % mesh.shape["model"] == 0 else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp if dp else None, None, tp))
    )


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, kind: str, cross: bool = False) -> PyTree:
    ks = jax.random.split(rng, 6)
    p: Dict[str, PyTree] = {"norm1": L.init_norm(cfg)}
    if kind in ("G", "L", "B"):
        p["attn"] = L.init_attention(ks[0], cfg)
        p["norm2"] = L.init_norm(cfg)
        if cfg.n_experts > 0:
            p["moe"] = MoE.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
        if cross:
            p["cross_norm"] = L.init_norm(cfg)
            p["cross_attn"] = L.init_attention(ks[2], cfg)
    elif kind == "R":
        p["rglru"] = RG.init_rglru(ks[0], cfg)
        p["norm2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind == "M":
        p["ssd"] = SSD.init_ssd(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def apply_block(
    p: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    kind: str,
    positions: jnp.ndarray,
    cache: Optional[PyTree] = None,
    decode_pos=None,
    enc_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    moe_impl: str = "sort",
    seq_lens=None,
    slot_ids=None,
    page_tables=None,
    page_size: int = 0,
) -> Tuple[jnp.ndarray, Optional[PyTree], jnp.ndarray, jnp.ndarray]:
    """Returns (x, new_cache, aux_loss, expert_overflow)."""
    aux = jnp.zeros((), jnp.float32)
    overflow = jnp.zeros((), jnp.int32)
    new_cache = cache
    if kind in ("G", "L", "B"):
        h = L.apply_norm(p["norm1"], x, cfg)
        attn_cache = None if cache is None else cache.get("attn")
        y, attn_cache = L.apply_attention(
            p["attn"], h, cfg, kind, positions, attn_cache, decode_pos=decode_pos,
            seq_lens=seq_lens, slot_ids=slot_ids, page_tables=page_tables,
            page_size=page_size,
        )
        x = x + y
        if enc_kv is not None and "cross_attn" in p:
            h = L.apply_norm(p["cross_norm"], x, cfg)
            y, _ = L.apply_attention(p["cross_attn"], h, cfg, "X", positions, cross_kv=enc_kv)
            x = x + y
        h = L.apply_norm(p["norm2"], x, cfg)
        if cfg.n_experts > 0:
            if moe_impl == "capacity":
                valid = None
                if seq_lens is not None:
                    valid = jnp.arange(h.shape[1])[None, :] < seq_lens[:, None]
                elif slot_ids is not None:
                    valid = (slot_ids >= 0)[None, :]
                y, aux, overflow = MoE.apply_moe_capacity(p["moe"], h, cfg, valid=valid)
            else:
                y, aux = MoE.apply_moe(p["moe"], h, cfg, impl=moe_impl)
        else:
            y = L.apply_mlp(p["mlp"], h, cfg)
        x = x + y
        if cache is not None:
            new_cache = dict(cache)
            new_cache["attn"] = attn_cache
    elif kind == "R":
        h = L.apply_norm(p["norm1"], x, cfg)
        rg_cache = None if cache is None else cache.get("rglru")
        y, rg_cache = RG.apply_rglru(
            p["rglru"], h, cfg, rg_cache, seq_lens=seq_lens, slot_ids=slot_ids
        )
        x = x + y
        h = L.apply_norm(p["norm2"], x, cfg)
        x = x + L.apply_mlp(p["mlp"], h, cfg)
        if cache is not None:
            new_cache = dict(cache)
            new_cache["rglru"] = rg_cache
    elif kind == "M":
        h = L.apply_norm(p["norm1"], x, cfg)
        ssd_cache = None if cache is None else cache.get("ssd")
        y, ssd_cache = SSD.apply_ssd(
            p["ssd"], h, cfg, ssd_cache, seq_lens=seq_lens, slot_ids=slot_ids
        )
        x = x + y
        if cache is not None:
            new_cache = dict(cache)
            new_cache["ssd"] = ssd_cache
    return x, new_cache, aux, overflow


def init_block_cache(
    cfg: ModelConfig, kind: str, batch: int, seq_len: int, cross: bool = False,
    linear: bool = False,
):
    c: Dict[str, PyTree] = {}
    if kind in ("G", "L", "B"):
        c["attn"] = L.init_attention_cache(cfg, kind, batch, seq_len, linear=linear)
    elif kind == "R":
        c["rglru"] = RG.init_rglru_cache(cfg, batch)
    elif kind == "M":
        c["ssd"] = SSD.init_ssd_cache(cfg, batch)
    return c


# ---------------------------------------------------------------------------
# Layer stack (grouped scan)
# ---------------------------------------------------------------------------


def _unit_and_groups(cfg: ModelConfig) -> Tuple[str, int, int]:
    unit = cfg.layer_pattern
    n_groups = cfg.n_layers // len(unit)
    tail = cfg.n_layers % len(unit)
    return unit, n_groups, tail


def init_stack(rng, cfg: ModelConfig, cross: bool = False) -> PyTree:
    unit, n_groups, tail = _unit_and_groups(cfg)
    groups = []
    for j, kind in enumerate(unit):
        rngs = jax.random.split(jax.random.fold_in(rng, j), n_groups)
        stacked = jax.vmap(lambda r: init_block(r, cfg, kind, cross))(rngs)
        groups.append(stacked)
    tail_ps = [
        init_block(jax.random.fold_in(rng, 1000 + i), cfg, cfg.pattern[n_groups * len(unit) + i], cross)
        for i in range(tail)
    ]
    return {"groups": tuple(groups), "tail": tail_ps}


def init_stack_cache(cfg: ModelConfig, batch: int, seq_len: int, linear: bool = False) -> PyTree:
    unit, n_groups, tail = _unit_and_groups(cfg)
    groups = []
    for kind in unit:
        one = init_block_cache(cfg, kind, batch, seq_len, linear=linear)
        stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), one)
        groups.append(stacked)
    tail_cs = [
        init_block_cache(cfg, cfg.pattern[n_groups * len(unit) + i], batch, seq_len, linear=linear)
        for i in range(tail)
    ]
    return {"groups": tuple(groups), "tail": tail_cs}


def apply_stack(
    params: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    caches: Optional[PyTree] = None,
    decode_pos=None,
    enc_kv_fn=None,
    moe_impl: str = "sort",
    seq_lens=None,
    slot_ids=None,
    page_tables=None,
    page_size: int = 0,
) -> Tuple[jnp.ndarray, Optional[PyTree], jnp.ndarray, jnp.ndarray]:
    """Apply all layers. enc_kv_fn(block_params, ) is handled by encdec path
    in model.py via per-block cross KV computed there (cross_kv passed as a
    stacked tensor through scan is handled by the caller precomputing KV).

    Returns (x, new_caches, aux_loss, expert_overflow) — overflow is the
    stack-total count of MoE routes dropped past capacity (always 0 for
    non-capacity moe_impl).
    """
    unit, n_groups, tail = _unit_and_groups(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    ovf_total = jnp.zeros((), jnp.int32)

    def group_body(carry, xs):
        x, aux, ovf = carry
        group_params, group_caches = xs
        if group_caches is None:
            x = constrain_activations(x, cfg)
        new_caches = []
        for j, kind in enumerate(unit):
            cache_j = None if group_caches is None else group_caches[j]
            x, nc, a, o = apply_block(
                group_params[j], x, cfg, kind, positions, cache_j,
                decode_pos=decode_pos, moe_impl=moe_impl, seq_lens=seq_lens,
                slot_ids=slot_ids, page_tables=page_tables, page_size=page_size,
            )
            new_caches.append(nc)
            aux = aux + a
            ovf = ovf + o
        out = tuple(new_caches) if group_caches is not None else None
        return (x, aux, ovf), out

    body = group_body
    if cfg.remat and caches is None:
        body = jax.checkpoint(group_body, prevent_cse=False)

    if n_groups > 0:
        xs = (params["groups"], caches["groups"] if caches is not None else None)
        if caches is None:
            # scan needs a concrete xs pytree: pair params only
            (x, aux_total, ovf_total), _ = jax.lax.scan(
                lambda c, gp: body(c, (gp, None)), (x, aux_total, ovf_total),
                params["groups"]
            )
            new_group_caches = None
        else:
            (x, aux_total, ovf_total), new_group_caches = jax.lax.scan(
                body, (x, aux_total, ovf_total), xs
            )
    else:
        new_group_caches = caches["groups"] if caches is not None else None

    new_tail = []
    for i, p in enumerate(params["tail"]):
        kind = cfg.pattern[n_groups * len(unit) + i]
        cache_i = None if caches is None else caches["tail"][i]

        def run(p_, x_, kind_=kind):
            return apply_block(
                p_, x_, cfg, kind_, positions, None, moe_impl=moe_impl
            )

        if cfg.remat and caches is None:
            x, _, a, o = jax.checkpoint(run, prevent_cse=False)(p, x)
            nc = None
        else:
            x, nc, a, o = apply_block(
                p, x, cfg, kind, positions, cache_i, decode_pos=decode_pos,
                moe_impl=moe_impl, seq_lens=seq_lens, slot_ids=slot_ids,
                page_tables=page_tables, page_size=page_size,
            )
        new_tail.append(nc)
        aux_total = aux_total + a
        ovf_total = ovf_total + o

    if caches is None:
        return x, None, aux_total, ovf_total
    return x, {"groups": new_group_caches, "tail": new_tail}, aux_total, ovf_total
