"""Public model API: init / forward / loss / decode, for every family.

    params = init_params(rng, cfg)
    logits, aux = forward(params, cfg, batch)           # train / prefill
    loss_sum, w = loss_fn(params, cfg, batch)           # DropCompute GradFn
    cache = init_decode_cache(params, cfg, batch, L)    # serving
    logits, cache = decode_step(params, cfg, cache, tok, pos)

``batch`` is a dict with (family-dependent):
    tokens   (B, S) int32          — always
    weights  (B, S) float          — per-token loss weights (0 = pad/prefix)
    prefix   (B, P, d) bf16        — VLM patch embeddings (stub frontend)
    frames   (B, F, d) bf16        — audio encoder frames (stub frontend)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from .transformer import (
    apply_block,
    apply_stack,
    init_block,
    init_block_cache,
    init_stack,
    init_stack_cache,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig) -> PyTree:
    cfg.validate()
    ks = jax.random.split(rng, 8)
    p: Dict[str, PyTree] = {
        "embed": L.init_embedding(ks[0], cfg),
        "stack": init_stack(ks[1], cfg),
        "final_norm": L.init_norm(cfg),
    }
    if cfg.is_encdec:
        p["encoder"] = {
            "blocks": [init_block(jax.random.fold_in(ks[2], i), cfg, "B") for i in range(cfg.enc_layers)],
            "final_norm": L.init_norm(cfg),
            "pos_embedding": L.dense_init(ks[3], (cfg.enc_seq, cfg.d_model), in_axis=1, dtype=cfg.params_dtype),
        }
        # decoder cross-attention blocks replace the plain stack
        p["stack"] = {
            "groups": (),
            "tail": [
                init_block(jax.random.fold_in(ks[4], i), cfg, "G", cross=True)
                for i in range(cfg.n_layers)
            ],
        }
    return p


# ---------------------------------------------------------------------------
# Encoder (audio; the conv/mel frontend is a stub per the assignment)
# ---------------------------------------------------------------------------


def encode(params: PyTree, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    enc = params["encoder"]
    x = frames.astype(cfg.compute_dtype)
    x = x + enc["pos_embedding"][None, : x.shape[1]].astype(cfg.compute_dtype)
    positions = jnp.arange(x.shape[1])

    def run(blk_, x_):
        y, _, _, _ = apply_block(blk_, x_, cfg, "B", positions)
        return y

    for blk in enc["blocks"]:
        x = jax.checkpoint(run, prevent_cse=False)(blk, x) if cfg.remat else run(blk, x)
    return L.apply_norm(enc["final_norm"], x, cfg)


def _cross_kv(blk, enc_out, cfg: ModelConfig):
    cd = cfg.compute_dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, blk["cross_attn"]["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, blk["cross_attn"]["wv"].astype(cd))
    return k, v


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward_features(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    moe_impl: str = "sort",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (final hidden states (B, S_text, d), aux_loss scalar)."""
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])

    if cfg.prefix_len > 0:  # VLM: prepend patch embeddings
        prefix = batch["prefix"].astype(cfg.compute_dtype)
        x_text = L.embed(params["embed"], tokens, cfg, positions)
        x = jnp.concatenate([prefix, x_text], axis=1)
        positions = jnp.arange(x.shape[1])
    else:
        x = L.embed(params["embed"], tokens, cfg, positions)

    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"])
        aux = jnp.zeros((), jnp.float32)

        def run(blk_, x_, enc_):
            y, _, a_, _ = apply_block(
                blk_, x_, cfg, "G", positions, enc_kv=_cross_kv(blk_, enc_, cfg)
            )
            return y, a_

        for blk in params["stack"]["tail"]:
            if cfg.remat:
                x, a = jax.checkpoint(run, prevent_cse=False)(blk, x, enc_out)
            else:
                x, a = run(blk, x, enc_out)
            aux = aux + a
    else:
        x, _, aux, _ = apply_stack(params["stack"], x, cfg, positions, moe_impl=moe_impl)

    x = L.apply_norm(params["final_norm"], x, cfg)
    if cfg.prefix_len > 0:
        x = x[:, cfg.prefix_len :]
    return x, aux


def forward(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    moe_impl: str = "sort",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B, S, V), aux_loss scalar)."""
    x, aux = forward_features(params, cfg, batch, moe_impl=moe_impl)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, aux


# ---------------------------------------------------------------------------
# Loss (sum form, for DropCompute's accumulate_grads)
# ---------------------------------------------------------------------------

_CE_CHUNK = 1024  # sequence positions per unembed+CE chunk


def _ce_sums(params, cfg, x, targets, w):
    """(loss_sum, weight_sum) from final hiddens; chunked over sequence.

    The unembed logits (B, S, V) in fp32 dominate training memory at large
    vocabs (several full copies live through the CE backward).  Chunking
    the positions through a checkpointed map keeps logits transient.
    """
    b, s, d = x.shape
    if s <= _CE_CHUNK:
        return _ce_once(params, cfg, x, targets, w)

    pad = (-s) % _CE_CHUNK
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, 0), (0, pad)))
    n = (s + pad) // _CE_CHUNK
    xc = jnp.moveaxis(x.reshape(b, n, _CE_CHUNK, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, n, _CE_CHUNK), 1, 0)
    wc = jnp.moveaxis(w.reshape(b, n, _CE_CHUNK), 1, 0)

    def one(args):
        return _ce_once(params, cfg, *args)

    sums = jax.lax.map(jax.checkpoint(one), (xc, tc, wc))
    return jnp.sum(sums[0]), jnp.sum(sums[1])


def _ce_once(params, cfg, x, targets, w):
    logits = L.unembed(params["embed"], x, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - tgt) * w), jnp.sum(w)


def loss_fn(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    moe_impl: str = "sort",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Next-token CE. Returns (loss_sum, token_weight_sum)."""
    x, aux = forward_features(params, cfg, batch, moe_impl=moe_impl)
    targets = batch["tokens"][:, 1:]
    w = batch.get("weights")
    w = jnp.ones_like(targets, jnp.float32) if w is None else w[:, 1:].astype(jnp.float32)
    loss_sum, w_sum = _ce_sums(params, cfg, x[:, :-1], targets, w)
    loss_sum = loss_sum + cfg.router_aux_weight * aux * w_sum
    return loss_sum, w_sum


def per_token_losses(params, cfg, batch, moe_impl: str = "sort"):
    """(B, S-1) CE and weights — for the per-example-weight SPMD step."""
    logits, aux = forward(params, cfg, batch, moe_impl=moe_impl)
    targets = batch["tokens"][:, 1:]
    w = batch.get("weights")
    w = jnp.ones_like(targets, jnp.float32) if w is None else w[:, 1:].astype(jnp.float32)
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    return lse - tgt, w, aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


class UnsupportedPatternError(NotImplementedError):
    """A serving path was asked for a layer pattern it cannot run.

    Typed (and raised unconditionally, not ``assert``-ed — asserts vanish
    under ``python -O``) so callers can catch it and fall back to
    ``decode_step`` token streaming for recurrent/SSM models.
    """


def require_chunkable(cfg: ModelConfig, what: str = "chunked prefill") -> None:
    """Raise ``UnsupportedPatternError`` unless ``cfg`` supports multi-token
    serving steps (decoder-only; any mix of 'G'/'L'/'R'/'M' layers —
    recurrent state is advanced by the chunk/segment scan, attention KV by
    multi-row cache writes).  Enc-dec models stay decode_step-only."""
    if not set(cfg.pattern) <= {"G", "L", "R", "M"}:
        raise UnsupportedPatternError(
            f"{what} supports 'G'/'L'/'R'/'M' layer patterns, got "
            f"{cfg.pattern!r}"
        )
    if cfg.is_encdec:
        raise UnsupportedPatternError(f"{what} does not support enc-dec models")


def _cache_parts(cache):
    """Split a decode cache into (data, page_tables, page_size).

    Every serving path accepts either the legacy cache dict (dense slots)
    or a ``repro.serve.kv.KVState`` — duck-typed on its ``data`` attribute
    so ``models`` never imports ``serve``.  For a dense ``KVState`` (or a
    plain dict) the tables are ``None`` and the model paths behave exactly
    as before; for a paged one, every scatter/gather translates
    ``(slot, position)`` through the block tables.
    """
    data = getattr(cache, "data", cache)
    return data, getattr(cache, "tables", None), getattr(cache, "page_size", 0)


def _cache_rebuild(cache, new_data):
    """Rewrap updated cache data in the caller's container type."""
    if hasattr(cache, "data"):
        return dataclasses.replace(cache, data=new_data)
    return new_data


def init_decode_cache(
    params: PyTree,
    cfg: ModelConfig,
    batch: int,
    seq_len: int,
    enc_out: Optional[jnp.ndarray] = None,
    linear: bool = False,
) -> PyTree:
    """Pre-allocated KV/state cache for ``decode_step`` / ``prefill_chunk``.

    linear=True allocates full-length (non-ring) buffers for sliding-window
    layers; required by ``prefill_chunk`` (the serving engine), whose
    multi-token scatter writes assume absolute positions never wrap.

    This builds the dense-slot layout; serving callers that want paged KV
    (or the cache API in general) go through ``repro.serve.kv.KVCacheSpec``
    — every decode path here accepts its ``KVState`` in place of the dict.
    """
    if cfg.is_encdec:
        # the enc-dec decoder stack is tail-only (see init_params): its
        # cache must mirror that structure, not the grouped-scan layout
        if enc_out is None:  # typed, not assert: must survive python -O
            raise ValueError("enc-dec decode needs encoder output (enc_out)")
        cache: Dict[str, PyTree] = {
            "stack": {
                "groups": (),
                "tail": [
                    init_block_cache(cfg, "G", batch, seq_len)
                    for _ in range(cfg.n_layers)
                ],
            },
            "cross_kv": [
                _cross_kv(blk, enc_out, cfg) for blk in params["stack"]["tail"]
            ],
        }
        return cache
    return {"stack": init_stack_cache(cfg, batch, seq_len, linear=linear)}


def prefill_chunk(
    params: PyTree,
    cfg: ModelConfig,
    cache: PyTree,
    tokens: jnp.ndarray,  # (B, C) int32
    pos: jnp.ndarray,  # (B,) first absolute position per slot
    seq_lens: jnp.ndarray,  # (B,) active token count per slot (0 = idle)
    moe_impl: str = "dense",
    return_aux: bool = False,
) -> Tuple[jnp.ndarray, PyTree]:
    """Process up to C prompt tokens per slot in one step (chunked prefill).

    Slot i consumes ``tokens[i, :seq_lens[i]]`` at absolute positions
    ``pos[i]..pos[i]+seq_lens[i]-1``, writing its KV-cache rows there;
    padding columns neither write the cache nor produce meaningful logits.
    Returns logits for every (slot, column) — the caller reads column
    ``seq_lens[i]-1`` when slot i just finished its prompt, or column 0
    for a single-token decode slot.  With C == 1 and seq_lens in {0, 1}
    this is a decode step that skips idle slots, so one function serves
    the whole mixed decode+prefill engine iteration.

    The cache must be allocated with ``init_decode_cache(..., linear=True)``
    (no ring buffers).  Recurrent layers ('R'/'M') run a chunk scan seeded
    from (and writing back to) their per-slot carried state — columns past
    a row's ``seq_lens`` are an exact state identity, so idle slots keep
    their state bit-for-bit.

    ``return_aux=True`` (static) additionally returns a per-step stats
    dict — currently ``{"expert_overflow"}``, the count of MoE routes
    dropped past expert capacity this step (0 unless
    ``moe_impl="capacity"``).

    Host-side driver loops must synchronize each step (e.g.
    ``jax.block_until_ready`` or materializing the sampled token) before
    reusing the host-side token/position buffers: with async dispatch,
    jax<=0.4 CPU can read freed host memory mid-execution otherwise.
    ``ContinuousBatcher`` does this for you.

    ``cache`` is the legacy dict from ``init_decode_cache`` or a
    ``repro.serve.kv.KVState`` (dense or paged); the returned cache has
    the same container type as the input.
    """
    require_chunkable(cfg, "chunked prefill")
    data, tables, page_size = _cache_parts(cache)
    pos = jnp.asarray(pos)
    c = tokens.shape[1]
    positions = pos[:, None] + jnp.arange(c)[None, :]  # (B, C) for RoPE
    x = L.embed(params["embed"], tokens, cfg, positions)
    x, new_stack, _, ovf = apply_stack(
        params["stack"], x, cfg, positions, data["stack"],
        decode_pos=pos, seq_lens=jnp.asarray(seq_lens), moe_impl=moe_impl,
        page_tables=tables, page_size=page_size,
    )
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)
    out_cache = _cache_rebuild(cache, {"stack": new_stack})
    if return_aux:
        return logits, out_cache, {"expert_overflow": ovf}
    return logits, out_cache


def verify_step(
    params: PyTree,
    cfg: ModelConfig,
    cache: PyTree,
    tokens: jnp.ndarray,  # (B, 1 + k) int32: [last emitted, draft_1..draft_k]
    pos: jnp.ndarray,  # (B,) first absolute position per slot
    seq_lens: jnp.ndarray,  # (B,) 1 + drafts granted per slot (0 = idle)
    moe_impl: str = "dense",
) -> Tuple[jnp.ndarray, PyTree]:
    """Speculative-decoding verify path: score ``k`` draft tokens per slot
    in one bounded step, returning **per-position** logits.

    Row i carries ``[t_last, d_1, .., d_k]`` at the slot's absolute
    positions; column ``j`` of the returned ``(B, 1 + k, V)`` logits is
    the model's **full** next-token distribution after consuming the row
    through column ``j`` — per-column probabilities, not a pre-reduced
    argmax, which is what both acceptance rules need: greedy acceptance
    keeps the longest prefix where ``d_{j+1} == argmax(logits[:, j])``,
    and rejection-sampling acceptance (``serve.spec.accept_sampled``)
    samples each column with the request's own params and per-position
    PRNG key (``serve.sampling.sample_tokens``) and keeps the prefix the
    samples confirm — the first mismatching column supplies the
    bonus/resampled token either way.  This *is* ``prefill_chunk``:
    verification
    is chunked prefill at the slot's absolute positions (the same
    shape-stable compiled program family as mixed prefill+decode steps),
    which means the drafts' KV lands in the cache as a side effect and
    the accepted prefix needs no recompute.  Rejected positions are the
    caller's rollback: a position-mask trim for dense slots (stale rows
    are never attended) or ``KVCache.trim_slot`` for the paged layout.

    The serving engine's jitted step IS this program (one compiled step
    serves prefill, decode, and verify grants alike); this named entry
    point is the contract for direct callers and is pinned against a
    sequential ``decode_step`` loop in ``tests/test_serve_spec.py``.
    """
    return prefill_chunk(params, cfg, cache, tokens, pos, seq_lens, moe_impl=moe_impl)


def packed_prefill(
    params: PyTree,
    cfg: ModelConfig,
    cache: PyTree,
    tokens: jnp.ndarray,  # (P,) int32 packed granted tokens
    slot_ids: jnp.ndarray,  # (P,) int32 cache slot per token (< 0 = padding)
    positions: jnp.ndarray,  # (P,) int32 absolute cache position per token
    moe_impl: str = "dense",
    return_aux: bool = False,
) -> Tuple[jnp.ndarray, PyTree]:
    """Token-packed engine step: granted tokens alone determine compute.

    The dense ``prefill_chunk`` computes the full (B, C) shape however few
    tokens the scheduler granted; this path takes the flattened layout
    from ``repro.serve.packing`` — one row per granted token, P fixed at
    the engine's packed capacity — and runs the whole mixed decode+prefill
    iteration as a single (1, P) batch.  Each token writes its K/V into
    ``cache`` at (slot_ids[j], positions[j]) and attends only within its
    own slot (segment-aware masking via the per-token slot gather; see
    ``apply_attention``), so requests packed side by side can never leak
    into each other.  Returns logits (P, V); the caller reads each slot's
    final granted row.  Same cache contract as ``prefill_chunk``:
    ``init_decode_cache(..., linear=True)`` — or a paged
    ``repro.serve.kv.KVState``, whose block tables route every
    ``(slot, position)`` to its physical page row.  Recurrent layers
    ('R'/'M') run a segment-masked scan over the packed axis: each
    segment injects its slot's carried state at its first token and the
    last token writes the state back (``models/recurrent.py``); the
    pack_step invariant that a slot's tokens are contiguous is what makes
    one global scan per step sound.  ``return_aux`` as in
    ``prefill_chunk``.
    """
    require_chunkable(cfg, "packed prefill")
    data, tables, page_size = _cache_parts(cache)
    tokens = jnp.asarray(tokens)[None]  # (1, P)
    pos2 = jnp.asarray(positions)[None]  # (1, P)
    x = L.embed(params["embed"], tokens, cfg, pos2)
    x, new_stack, _, ovf = apply_stack(
        params["stack"], x, cfg, pos2, data["stack"],
        slot_ids=jnp.asarray(slot_ids), moe_impl=moe_impl,
        page_tables=tables, page_size=page_size,
    )
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)
    out_cache = _cache_rebuild(cache, {"stack": new_stack})
    if return_aux:
        return logits[0], out_cache, {"expert_overflow": ovf}
    return logits[0], out_cache


def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    cache: PyTree,
    token: jnp.ndarray,  # (B, 1) int32
    pos: jnp.ndarray,  # scalar int32, or (B,) per-slot positions
    moe_impl: str = "dense",
) -> Tuple[jnp.ndarray, PyTree]:
    data, tables, page_size = _cache_parts(cache)
    pos = jnp.asarray(pos)
    positions = pos[:, None] if pos.ndim else jnp.full((1,), pos, jnp.int32)
    x = L.embed(params["embed"], token, cfg, positions)

    if tables is not None and cfg.is_encdec:
        raise UnsupportedPatternError("paged KV does not support enc-dec models")
    if cfg.is_encdec:
        new_tail = []
        for blk, c, kv in zip(
            params["stack"]["tail"], data["stack"]["tail"], data["cross_kv"]
        ):
            x, nc, _, _ = apply_block(
                blk, x, cfg, "G", positions, c, decode_pos=pos, enc_kv=kv
            )
            new_tail.append(nc)
        new_data = {
            "stack": {"groups": data["stack"]["groups"], "tail": new_tail},
            "cross_kv": data["cross_kv"],
        }
    else:
        x, new_stack, _, _ = apply_stack(
            params["stack"], x, cfg, positions, data["stack"],
            decode_pos=pos, moe_impl=moe_impl,
            page_tables=tables, page_size=page_size,
        )
        new_data = {"stack": new_stack}

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, _cache_rebuild(cache, new_data)
