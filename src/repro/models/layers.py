"""Core neural layers: norms, RoPE, GQA attention (global/sliding), MLPs.

Pure-functional: every layer is ``init_*(rng, cfg) -> params`` plus an
``apply`` function.  Parameters are stored in ``cfg.param_dtype`` and cast
to ``cfg.dtype`` at use (bf16 compute, fp32 master — the TPU-native recipe).

Sharding is *not* baked in here; ``repro.dist.sharding`` assigns logical
axes to parameters by path-pattern and maps them onto the device mesh.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kernel_ops
from .config import ModelConfig

PyTree = Any


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, in_axis: int = 0, scale: float = 1.0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else math.prod(
        shape[a] for a in in_axis
    )
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    p = {"scale": jnp.ones((cfg.d_model,), cfg.params_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), cfg.params_dtype)
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    # Statistics in fp32, elementwise math in the compute dtype: avoids a
    # full fp32 image of x that XLA would otherwise hoist out of the layer
    # scan and stack across layers (observed: +12 GiB/device at 94L).
    if cfg.norm == "rmsnorm":
        ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
        return x * inv * p["scale"].astype(x.dtype)
    mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x.astype(jnp.float32) - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = (x.astype(jnp.float32) - mu) * inv
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps: float = 1e-6):
    """Per-head RMS norm for QK-norm (Qwen3-style)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, global or sliding-window, optional bias/QK-norm/softcap)
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype=cfg.params_dtype),
        "wk": dense_init(ks[1], (d, kv, hd), dtype=cfg.params_dtype),
        "wv": dense_init(ks[2], (d, kv, hd), dtype=cfg.params_dtype),
        "wo": dense_init(ks[3], (h, hd, d), in_axis=(0, 1), dtype=cfg.params_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), cfg.params_dtype)
        p["bk"] = jnp.zeros((kv, hd), cfg.params_dtype)
        p["bv"] = jnp.zeros((kv, hd), cfg.params_dtype)
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), cfg.params_dtype)
        p["k_norm"] = jnp.ones((cfg.hd,), cfg.params_dtype)
    return p


def _qkv(p, x, cfg: ModelConfig, positions):
    cd = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.use_qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sdpa(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: Optional[jnp.ndarray],
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Reference scaled-dot-product attention with GQA head expansion.

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D); mask broadcastable to
    (B, H, Sq, Sk) (True = attend).  The Pallas flash kernel in
    ``repro.kernels`` is the TPU hot-path replacement; this jnp path is the
    oracle and the CPU/dry-run path.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qh = q.reshape(b, sq, kvh, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits / math.sqrt(d)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask is not None:
        m = mask.reshape(b, kvh, g, *mask.shape[-2:]) if mask.shape[1] == h else mask[:, :, None]
        logits = jnp.where(m, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def sdpa_flash(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Double-blocked online-softmax attention (XLA-level flash).

    ``lax.map`` over query chunks (each chunk ``jax.checkpoint``-ed so
    backward recomputes logits instead of saving them) with an inner
    ``lax.scan`` over KV chunks maintaining the running max/sum.  Memory is
    O(q_chunk * k_chunk) per step instead of O(Sq * Sk) — required for the
    prefill_32k / long_500k shapes and for fp32-logit training at 4k.
    This is the same decomposition the Pallas kernel
    (repro.kernels.flash_attention) implements natively on TPU.
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)

    pad_q = (-sq) % q_chunk
    pad_k = (-sk) % k_chunk
    qg = q.reshape(b, sq, kvh, g, d).astype(jnp.float32) / math.sqrt(d)
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq = (sq + pad_q) // q_chunk
    nk = (sk + pad_k) // k_chunk
    kc = jnp.moveaxis(kp.reshape(b, nk, k_chunk, kvh, d), 1, 0)
    vc = jnp.moveaxis(vp.reshape(b, nk, k_chunk, kvh, d), 1, 0)

    def one_q_chunk(qi):
        q_c = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        qpos = qi * q_chunk + jnp.arange(q_chunk) + (sk - sq)  # right-aligned

        def body(carry, xs):
            acc, m_prev, l_prev, c = carry
            k_c, v_c = xs
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_c, k_c.astype(jnp.float32))
            if softcap > 0:
                logits = softcap * jnp.tanh(logits / softcap)
            kpos = c * k_chunk + jnp.arange(k_chunk)
            mask = kpos[None, :] < sk  # mask K padding
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
            alpha = jnp.exp(m_prev - m_cur)
            p = jnp.exp(logits - m_cur[..., None])
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_c.astype(jnp.float32)
            )
            return (acc, m_cur, l_cur, c + 1), None

        acc0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, kvh, g, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        (acc, m, l, _), _ = jax.lax.scan(body, (acc0, m0, l0, 0), (kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (b, q_chunk, kvh, g, d)

    outs = jax.lax.map(jax.checkpoint(one_q_chunk), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq + pad_q, h, d)
    if pad_q:
        out = out[:, :sq]
    return out.astype(q.dtype)


def sdpa_local_banded(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    window: int,
    block: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Sliding-window attention as a banded block computation.

    Each query block of size ``block`` attends only to keys in
    [i*block - window, i*block + block) — compute O(Sq * (window+block))
    instead of O(Sq * Sk).  This is the sub-quadratic structure that lets
    SWA architectures run the long-context shapes.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    block = block or min(window, sq)
    n_blocks = sq // block
    if n_blocks * block != sq:
        # raised, not assert-ed (python -O): a ragged final block would
        # otherwise be silently truncated by the reshape below
        raise ValueError(
            f"banded SWA needs seq len {sq} divisible by block {block}"
        )
    band = window + block  # keys visible to one query block

    qg = q.reshape(b, n_blocks, block, kvh, g, d).astype(jnp.float32) / math.sqrt(d)
    # left-pad keys/values by `window` so every block slice is in-range
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def blk(i, q_b):
        # keys for block i: padded positions [i*block, i*block + band)
        k_b = jax.lax.dynamic_slice_in_dim(kp, i * block, band, axis=1)
        v_b = jax.lax.dynamic_slice_in_dim(vp, i * block, band, axis=1)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_b, k_b.astype(jnp.float32))
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        qpos = i * block + jnp.arange(block)
        kpos = i * block - window + jnp.arange(band)  # absolute (pad offset)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - window) & (
            kpos[None, :] >= 0
        )
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_b.astype(jnp.float32))
        return out.reshape(b, block, h, d)

    outs = jax.lax.map(jax.checkpoint(lambda i: blk(i, qg[:, i])), jnp.arange(n_blocks))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, d).astype(q.dtype)


# Sequence length above which the memory-efficient paths kick in.
_CHUNKED_THRESHOLD = 2048


def _pad_heads_for_tp(q, k, v):
    """Pad-and-shard attention heads when they don't divide the TP axis.

    GSPMD requires even sharding, so architectures with e.g. 14 heads on a
    16-way model axis would otherwise run attention fully REPLICATED on the
    model axis (measured: ~11x useful-ratio loss on internvl2).  Instead:
    expand the KV heads to full MHA, zero-pad the head dim to the next
    model-axis multiple, and constrain heads onto the model axis — 1.14x
    padded compute replaces 16x replication.  Training path only (decode
    keeps GQA's small KV cache).  Returns (q, k, v, real_heads) with
    possibly padded head dims; caller slices the output back.
    """
    from .moe import _current_mesh  # lazy import (cycle)

    mesh = _current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return q, k, v, q.shape[2]
    tp = mesh.shape["model"]
    h, kvh = q.shape[2], k.shape[2]
    if tp <= 1 or h % tp == 0:
        return q, k, v, h
    from jax.sharding import NamedSharding, PartitionSpec as P

    g = h // kvh
    k = jnp.repeat(k, g, axis=2)  # expand KV -> full heads
    v = jnp.repeat(v, g, axis=2)
    h2 = -(-h // tp) * tp
    pad = h2 - h
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    while dp and q.shape[0] % n != 0:
        dp = dp[1:]
        n = max(n // mesh.shape.get(dp[0] if dp else "", 1), 1)
    spec = NamedSharding(mesh, P(dp if dp else None, None, "model", None))
    q = jax.lax.with_sharding_constraint(q, spec)
    k = jax.lax.with_sharding_constraint(k, spec)
    v = jax.lax.with_sharding_constraint(v, spec)
    return q, k, v, h


# ---------------------------------------------------------------------------
# Paged KV addressing (the jit half of repro.serve.kv's Paged layout).
# Defined here — not in serve.kv — because both the attention paths below
# and the serve package need it, and models must not import serve.
# ---------------------------------------------------------------------------


def paged_index(tables, slots, positions, page_size: int, num_pages: int):
    """Translate absolute ``(slot, position)`` into physical ``(page,
    offset)`` through the block tables.

    tables: (num_slots, num_blocks) int32, with ``num_pages`` marking
    unallocated blocks.  ``slots``/``positions`` are broadcast-compatible
    integer arrays.  Positions past the logical buffer (the padding
    convention) and unallocated blocks come back as ``page == num_pages``
    — out of range, so ``.at[...].set(..., mode="drop")`` discards the
    write and gathers never fetch them.
    """
    nb = tables.shape[-1]
    blk = positions // page_size
    page = tables[slots, jnp.minimum(blk, nb - 1)]
    return jnp.where(blk < nb, page, num_pages), positions % page_size


def paged_gather(pool, tables, slots):
    """Materialize each entry's logical KV buffer from the page pool.

    pool: (num_pages, page_size, kv, hd); tables: (num_slots, num_blocks);
    slots: (X,) int32.  Returns (X, num_blocks * page_size, kv, hd) in
    logical-position order (pages hold contiguous positions).  Oracle-only
    duty since the fused ``kernels.ops.paged_flash_attention`` took over
    the hot paths: rows behind unallocated blocks (the ``num_pages``
    sentinel) come back as explicit zero rows, never another slot's data —
    a hostile block table can redirect a read only to zeros, so isolation
    does not rest on downstream position masking.
    """
    num_pages = pool.shape[0]
    pages = tables[slots]  # (X, num_blocks)
    ok = (pages >= 0) & (pages < num_pages)
    safe = jnp.where(ok, pages, 0)
    out = jnp.where(
        ok[..., None, None, None], pool[safe], jnp.zeros((), pool.dtype)
    )  # (X, num_blocks, page_size, kv, hd)
    return out.reshape(out.shape[0], -1, *pool.shape[2:])


def _paged_quantize(rows):
    """Per-row symmetric int8 quantization for paged KV writes.

    rows: (..., KV, D) in compute dtype.  Returns int8 codes of the same
    shape plus f32 scales of shape (..., KV) — one scale per (token row,
    kv head), so already-written pages never need requantizing when a
    later token lands in the same page.
    """
    rf = rows.astype(jnp.float32)
    amax = jnp.max(jnp.abs(rf), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(rf / scale[..., None]), -127, 127).astype(jnp.int8)
    return codes, scale


def _paged_write(cache, page, off, k_rows, v_rows):
    """Scatter K/V rows into the paged pool at ``(page, off)``.

    ``page``/``off`` index arrays of shape S; ``k_rows``/``v_rows`` are
    (S..., KV, D).  Out-of-range pages (the unallocated sentinel from
    ``paged_index``) are dropped.  int8 pools (marked by the presence of
    ``k_scale``/``v_scale`` leaves) quantize each row and scatter its
    scale alongside.
    """
    new = dict(cache)
    if "k_scale" in cache:
        kq, ks = _paged_quantize(k_rows)
        vq, vs = _paged_quantize(v_rows)
        new["k"] = cache["k"].at[page, off].set(kq, mode="drop")
        new["v"] = cache["v"].at[page, off].set(vq, mode="drop")
        new["k_scale"] = cache["k_scale"].at[page, off].set(ks, mode="drop")
        new["v_scale"] = cache["v_scale"].at[page, off].set(vs, mode="drop")
    else:
        new["k"] = cache["k"].at[page, off].set(
            k_rows.astype(cache["k"].dtype), mode="drop"
        )
        new["v"] = cache["v"].at[page, off].set(
            v_rows.astype(cache["v"].dtype), mode="drop"
        )
    return new


def _paged_attend(q_tok, cache, page_tables, q_pos, q_slots, window, softcap):
    """Fused paged attention over flattened query tokens.

    q_tok: (T, H, D); returns (T, H, D).  One entry point for the decode,
    chunked-prefill, and token-packed paged branches — they all reduce to
    per-token ``(q_pos, q_slots)`` addressing, which is exactly the fused
    kernel's grid.  Dispatch (Pallas on TPU, fused XLA elsewhere) lives in
    ``kernels.ops``.
    """
    return kernel_ops.paged_flash_attention(
        q_tok, cache["k"], cache["v"], page_tables, q_pos, q_slots,
        window=window, softcap=softcap,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
    )


def causal_mask(sq: int, sk: int, q_offset=0, window: int = 0) -> jnp.ndarray:
    """(1, 1, Sq, Sk) boolean mask; window>0 = sliding window."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m[None, None]


def apply_attention(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    kind: str,
    positions: jnp.ndarray,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    decode_pos: Optional[jnp.ndarray] = None,
    seq_lens: Optional[jnp.ndarray] = None,
    slot_ids: Optional[jnp.ndarray] = None,
    page_tables: Optional[jnp.ndarray] = None,
    page_size: int = 0,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """One attention block application.

    kind: 'G' (global causal), 'L' (sliding window causal), 'B'
    (bidirectional, encoder), 'X' (cross-attention; uses cross_kv as K/V).

    cache (decode mode): {"k": (B, L, KV, D), "v": ...} — pre-allocated
    ring/linear buffer; this function writes the current token's K/V at
    ``decode_pos`` and attends over valid entries.

    seq_lens (chunked prefill): (B,) active token count per slot for a
    (B, C) chunk — slot i consumes x[i, :seq_lens[i]] at absolute
    positions decode_pos[i]..decode_pos[i]+seq_lens[i]-1; the remaining
    columns are padding (no cache write, output ignored).  Requires a
    linear cache (buffer length covers every absolute position, no ring
    wraparound); sliding windows are enforced through the mask instead.

    slot_ids (token-packed serving step): (P,) cache-slot id per packed
    token for an x of shape (1, P, d) — entry j is written to cache slot
    ``slot_ids[j]`` at absolute position ``positions[0, j]`` and attends
    only to that slot's rows at positions <= its own (its segment), so
    tokens from different requests packed into one step can never see
    each other.  ``slot_ids[j] < 0`` marks padding: no cache write, all
    keys masked, output ignored.  Requires a linear cache, like the
    chunked path.

    page_tables / page_size (paged KV layout, ``repro.serve.kv``): the
    cache leaves are a flat ``(num_pages, page_size, KV, D)`` pool shared
    by every slot instead of per-slot rows; writes go through the
    layout's ``paged_index`` translation (``(slot, pos)`` ->
    ``(table[slot, pos // page_size], pos % page_size)``) and reads
    through the fused ``kernels.ops.paged_flash_attention`` block-table
    walk (no whole-buffer materialization).  int8 pools carry
    ``k_scale``/``v_scale`` leaves: rows quantize at write time and
    dequantize inside the kernel's online-softmax loop.  The
    decode/chunked/packed semantics above are unchanged — the paged
    layout is token-identical to the dense one (int8 is allclose, not
    bit-identical); only the physical addressing differs.  Paged decode
    needs per-slot positions.
    """
    cd = cfg.compute_dtype
    window = cfg.sliding_window if kind == "L" else 0

    if kind == "X":
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
        if cfg.qkv_bias:
            q = q + p["bq"].astype(cd)
        k, v = cross_kv
        if q.shape[1] > _CHUNKED_THRESHOLD:
            out = sdpa_flash(q, k, v, causal=False, softcap=cfg.logit_softcap)
        else:
            out = sdpa(q, k, v, None, cfg.logit_softcap)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
        return y, cache

    q, k, v = _qkv(p, x, cfg, positions)

    if slot_ids is not None:
        # Token-packed step: x is (1, P, d), one flattened batch of this
        # iteration's granted tokens.  Scatter each token's K/V into its
        # slot's cache rows, then each query gathers its own slot's
        # buffer and attends causally within it — the segment mask falls
        # out of the gather (cross-slot keys are simply never fetched).
        # Compute is O(P * L), proportional to granted tokens P.
        # Raised, not assert-ed: under python -O a ring buffer here would
        # silently drop writes past the window instead of erroring.
        if cache is None:
            raise ValueError("packed step needs a decode cache")
        if page_tables is not None:
            buf_len = page_tables.shape[-1] * page_size
        else:
            buf_len = cache["k"].shape[1]
        if window > 0 and buf_len <= window:
            raise ValueError(
                f"packed step needs a linear cache "
                f"(init_decode_cache(..., linear=True)); got ring buffer of "
                f"{buf_len} rows for sliding window {window}"
            )
        slots = jnp.asarray(slot_ids)  # (P,)
        pos = jnp.asarray(positions).reshape(-1)  # (P,) absolute
        valid = slots >= 0
        slot_safe = jnp.where(valid, slots, 0)
        wp = jnp.where(valid, pos, buf_len)  # OOB => dropped by scatter
        if page_tables is not None:
            # Fused path: scatter this step's rows, then one kernel call
            # over the packed tokens — each query walks its own slot's
            # block table, so cost tracks granted tokens, not pool size,
            # and the segment mask is structural (cross-slot pages are
            # never read).  Padding tokens (slot < 0) return zero rows.
            num_pages = cache["k"].shape[0]
            page, off = paged_index(page_tables, slot_safe, wp, page_size, num_pages)
            cache = _paged_write(cache, page, off, k[0], v[0])
            out = _paged_attend(
                q[0], cache, page_tables, pos, slots, window, cfg.logit_softcap
            )  # (P, H, D)
            out = out[None]  # back to (1, P, H, D)
        else:
            ck = cache["k"].at[slot_safe, wp].set(
                k[0].astype(cache["k"].dtype), mode="drop"
            )
            cv = cache["v"].at[slot_safe, wp].set(
                v[0].astype(cache["v"].dtype), mode="drop"
            )
            kk = jnp.take(ck, slot_safe, axis=0)  # (P, L, KV, D)
            vv = jnp.take(cv, slot_safe, axis=0)
            kpos_idx = jnp.arange(buf_len)
            m = (kpos_idx[None, :] <= pos[:, None]) & valid[:, None]
            if window > 0:
                m &= kpos_idx[None, :] > pos[:, None] - window
            out = sdpa(
                q[0][:, None], kk.astype(cd), vv.astype(cd),
                m[:, None, None, :], cfg.logit_softcap,
            )  # (P, 1, H, D)
            out = out[:, 0][None]  # back to (1, P, H, D)
            cache = {"k": ck, "v": cv}
    elif cache is None:
        sq = x.shape[1]
        q, k, v, real_h = _pad_heads_for_tp(q, k, v)
        if kind == "L" and sq > 2 * window and sq % min(window, sq) == 0:
            out = sdpa_local_banded(q, k, v, window, softcap=cfg.logit_softcap)
        elif sq > _CHUNKED_THRESHOLD:
            out = sdpa_flash(q, k, v, causal=(kind != "B"), softcap=cfg.logit_softcap)
        else:
            if kind == "B":
                mask = None
            else:
                mask = causal_mask(sq, sq, window=window)
            out = sdpa(q, k, v, mask, cfg.logit_softcap)
        if out.shape[2] != real_h:
            out = out[:, :, :real_h]
    elif seq_lens is not None or x.shape[1] > 1:
        # Chunked prefill: write up to C tokens per slot at its absolute
        # positions, attend causally over the linear buffer.  Inactive
        # columns (col >= seq_lens[i]) scatter out of range and are
        # dropped, so previously written rows are never clobbered; active
        # write positions are distinct, so the scatter is race-free.
        if page_tables is not None:
            buf_len = page_tables.shape[-1] * page_size
        else:
            buf_len = cache["k"].shape[1]
        b, c = x.shape[:2]
        pos = jnp.asarray(decode_pos)
        if pos.ndim != 1:
            # typed, not assert-ed (python -O): a (B, 1) positions array
            # would broadcast into wrong scatter addresses silently
            raise ValueError(
                f"chunked prefill needs per-slot positions of shape (B,), "
                f"got ndim={pos.ndim}"
            )
        # A ring buffer (buf_len == window < seq_len) would silently drop
        # writes past the window here; require the linear layout.  (When
        # seq_len <= window the "ring" never wraps and buf_len != window;
        # the paged pool is linear by construction.)
        if window != 0 and buf_len <= window:
            raise ValueError(
                f"chunked prefill needs a linear cache "
                f"(init_decode_cache(..., linear=True)); got ring buffer of "
                f"{buf_len} rows for sliding window {window}"
            )
        offs = jnp.arange(c)
        qpos = pos[:, None] + offs[None, :]  # (B, C) absolute positions
        lens = jnp.full((b,), c, jnp.int32) if seq_lens is None else seq_lens
        active = offs[None, :] < lens[:, None]  # (B, C)
        wp = jnp.where(active, qpos, buf_len)  # OOB => dropped by scatter
        bidx = jnp.arange(b)[:, None]
        if page_tables is not None:
            # Fused path: flatten the (B, C) chunk to B*C packed tokens
            # (inactive columns become padding queries) — the same
            # per-token (q_pos, q_slots) grid the packed step uses, so
            # chunked prefill and speculative verify fuse for free.
            num_pages = cache["k"].shape[0]
            page, off = paged_index(page_tables, bidx, wp, page_size, num_pages)
            cache = _paged_write(cache, page, off, k, v)
            h = q.shape[2]
            q_slots = jnp.where(active, bidx, -1).reshape(-1)  # (B*C,)
            out = _paged_attend(
                q.reshape(b * c, h, -1), cache, page_tables,
                qpos.reshape(-1), q_slots, window, cfg.logit_softcap,
            ).reshape(b, c, h, -1)
        else:
            ck = cache["k"].at[bidx, wp].set(k.astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[bidx, wp].set(v.astype(cache["v"].dtype), mode="drop")
            kpos_idx = jnp.arange(buf_len)
            valid = kpos_idx[None, None, :] <= qpos[..., None]  # (B, C, L)
            if window > 0:
                valid &= kpos_idx[None, None, :] > qpos[..., None] - window
            out = sdpa(q, ck.astype(cd), cv.astype(cd), valid[:, None], cfg.logit_softcap)
            cache = {"k": ck, "v": cv}
    elif page_tables is not None:
        # Paged decode: one token per slot, addressed through the block
        # table.  Linear semantics (the window is enforced inside the
        # fused kernel), so no ring-position reconstruction is needed.
        # Each slot's query walks only its own admissible pages — decode
        # cost is proportional to its sequence length, not the pool.
        pos = jnp.asarray(decode_pos)
        if pos.ndim == 0:
            raise ValueError("paged decode needs per-slot positions, got a scalar")
        num_pages = cache["k"].shape[0]
        bidx = jnp.arange(q.shape[0])
        page, off = paged_index(page_tables, bidx, pos, page_size, num_pages)
        cache = _paged_write(cache, page, off, k[:, 0], v[:, 0])
        out = _paged_attend(
            q[:, 0], cache, page_tables, pos, bidx, window, cfg.logit_softcap
        )[:, None]  # (B, 1, H, D)
    else:
        # Decode: write K/V at cache position, attend over the buffer.
        # decode_pos is a scalar (lockstep batch) or (B,) per-slot vector
        # (continuous batching: every sequence at its own position).
        buf_len = cache["k"].shape[1]
        pos = jnp.asarray(decode_pos)
        kpos_idx = jnp.arange(buf_len)
        if pos.ndim == 0:
            slot = pos % buf_len if window > 0 else pos
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            pos_b = pos[None]  # (1,) broadcasts over batch below
            slot_b = slot[None]
        else:
            slot_b = pos % buf_len if window > 0 else pos  # (B,)
            bidx = jnp.arange(q.shape[0])
            ck = cache["k"].at[bidx, slot_b].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, slot_b].set(v[:, 0].astype(cache["v"].dtype))
            pos_b = pos
        if window > 0:
            # ring buffer: reconstruct each entry's absolute position.
            abs_pos = jnp.where(
                kpos_idx[None, :] <= slot_b[:, None],
                pos_b[:, None] - slot_b[:, None] + kpos_idx[None, :],
                pos_b[:, None] - slot_b[:, None] - buf_len + kpos_idx[None, :],
            )
            valid = (abs_pos >= jnp.maximum(pos_b[:, None] - window + 1, 0)) & (
                abs_pos <= pos_b[:, None]
            )
        else:
            valid = kpos_idx[None, :] <= pos_b[:, None]  # (B or 1, L)
        mask = valid[:, None, None, :]
        out = sdpa(q, ck.astype(cd), cv.astype(cd), mask, cfg.logit_softcap)
        cache = {"k": ck, "v": cv}

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, cache


def init_attention_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int, linear: bool = False):
    """Pre-allocated decode cache for one attention layer.

    linear=True allocates the full ``seq_len`` even for sliding-window
    layers (no ring wraparound) — required by the chunked-prefill path,
    which enforces the window through the attention mask instead and
    asserts ``buf > window`` to reject ring buffers (hence the +1 pad
    when seq_len == window).
    """
    if kind == "L":
        buf = max(seq_len, cfg.sliding_window + 1) if linear else min(cfg.sliding_window, seq_len)
    else:
        buf = seq_len
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, buf, kv, hd), cfg.compute_dtype),
        "v": jnp.zeros((batch, buf, kv, hd), cfg.compute_dtype),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {"w_out": dense_init(ks[2], (f, d), dtype=cfg.params_dtype)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], (d, f), dtype=cfg.params_dtype)
        p["w_in"] = dense_init(ks[1], (d, f), dtype=cfg.params_dtype)
    else:
        p["w_in"] = dense_init(ks[1], (d, f), dtype=cfg.params_dtype)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    cd = cfg.compute_dtype
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd))
        u = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(cd))
        act = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(cd)))
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(cd))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    p = {"embedding": dense_init(rng, (cfg.vocab_size, cfg.d_model), in_axis=1, dtype=cfg.params_dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(
            jax.random.fold_in(rng, 1), (cfg.d_model, cfg.vocab_size), dtype=cfg.params_dtype
        )
    if cfg.pos == "learned":
        p["pos_embedding"] = dense_init(
            jax.random.fold_in(rng, 2), (8192, cfg.d_model), in_axis=1, dtype=cfg.params_dtype
        )
    return p


def embed(p, tokens, cfg: ModelConfig, positions=None):
    x = jnp.take(p["embedding"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.family != "ssm":
        x = x * math.sqrt(cfg.d_model)
    if cfg.pos == "learned" and positions is not None:
        pe = jnp.take(p["pos_embedding"], positions % p["pos_embedding"].shape[0], axis=0)
        x = x + pe.astype(cfg.compute_dtype)
    return x


def unembed(p, x, cfg: ModelConfig):
    w = p.get("unembed")
    if w is None:
        w = p["embedding"].T
    return jnp.einsum("bsd,dv->bsv", x, w.astype(cfg.compute_dtype))
