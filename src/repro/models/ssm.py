"""Mamba-2 / SSD (state-space duality) block  [arXiv:2405.21060].

TPU-native adaptation of the SSD algorithm: the sequence is split into
chunks; within a chunk the recurrence is materialized as a (masked,
decay-weighted) attention-like matmul that feeds the MXU, and across
chunks a small recurrence over per-chunk states runs as a ``lax.scan``
(chunk count is seq/chunk, so the serial dimension is short).  This is the
standard SSD decomposition — quadratic-in-chunk, linear-in-sequence.

Decode maintains the SSM state (B, H, P, N) and a causal-conv ring state,
giving O(1) per-token cost (the reason mamba2 runs the ``long_500k``
shape).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init
from .recurrent import (
    chunked_conv_state,
    final_segment_decay,
    packed_conv,
    segment_info,
)


def init_ssd(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ks = jax.random.split(rng, 4)
    conv_ch = di + 2 * n
    return {
        # in_proj packs [z (gate), x, B, C, dt] like the reference impl.
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + nh), dtype=cfg.params_dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_ch), in_axis=0, dtype=cfg.params_dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.params_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(cfg.params_dtype),
        "dt_bias": jnp.zeros((nh,), cfg.params_dtype),
        "d_skip": jnp.ones((nh,), cfg.params_dtype),
        "norm_scale": jnp.ones((di,), cfg.params_dtype),
        "w_out": dense_init(ks[3], (di, d), dtype=cfg.params_dtype),
    }


def _split_proj(cfg: ModelConfig, proj):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt, di, n, nh


def _causal_conv(xbc, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d; state=(B, K-1, C) carries decode history."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)
    else:
        xp = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
    out = sum(xp[:, i : xp.shape[1] - (k - 1) + i] * w[i] for i in range(k))
    out = jax.nn.silu(out + b)
    new_state = xp[:, -(k - 1) :] if k > 1 else None
    return out, new_state


def _ssd_chunked(x, dt, a, b, c, chunk: int, init_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P)   dt: (B, S, H)   a: (H,) positive decay rates
    b, c: (B, S, N)   (single group, shared across heads — Mamba-2 default)
    ``init_state`` (B, H, N, P) seeds the inter-chunk recurrence (a slot's
    carried state during chunked prefill); None = zeros (training / fresh
    sequence).  Returns ``(y, final_state)`` with y (B, S, H, P) and
    final_state (B, H, N, P) — the recurrence state after the last token
    (for rows whose tail is dt=0 padding, padding is an exact identity,
    so this IS the state after each row's own last real token).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    nc = s // chunk
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = b.reshape(bs, nc, chunk, n)
    cc = c.reshape(bs, nc, chunk, n)

    da = dtc * a  # (B, nc, L, H): -dt*a is the log decay per step
    cum = jnp.cumsum(da, axis=2)  # cumulative log-decay within chunk

    # ---- intra-chunk (quadratic in chunk length; MXU-friendly) ----
    # decay(i, j) = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L,L,H)
    li = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask the exponent BEFORE exp: exp of the (positive) upper-triangle
    # values overflows and poisons the backward through where's 0*inf.
    decay = jnp.exp(-jnp.where(li, diff, 0.0)) * li
    scores = jnp.einsum("bgin,bgjn->bgij", cc, bc)  # (B,nc,L,L)
    att = scores[..., None] * decay * dtc[:, :, None, :, :]  # (B,nc,i,j,H)
    y_intra = jnp.einsum("bgijh,bgjhp->bgihp", att, xc)

    # ---- chunk states ----
    tail = cum[:, :, -1:, :] - cum  # decay from step j to chunk end
    wj = jnp.exp(-tail) * dtc  # (B,nc,L,H)
    states = jnp.einsum("bgjn,bgjh,bgjhp->bghnp", bc, wj, xc)  # (B,nc,H,N,P)

    # ---- inter-chunk recurrence over nc chunks ----
    chunk_decay = jnp.exp(-cum[:, :, -1, :])  # (B,nc,H)

    def scan_body(carry, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = (jnp.zeros_like(states[:, 0]) if init_state is None
            else init_state.astype(states.dtype))
    final, prev_states = jax.lax.scan(
        scan_body,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,N,P)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(-cum)  # decay from chunk start to step i
    y_inter = jnp.einsum(
        "bgin,bgih,bghnp->bgihp", cc, in_decay, prev_states
    )
    y = (y_intra + y_inter).reshape(bs, s, h, p)
    return y, final


def apply_ssd(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    seq_lens: Optional[jnp.ndarray] = None,
    slot_ids: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """One Mamba-2 block. x: (B, S, D).

    Cache selects the serving path: with ``seq_lens`` it is a dense
    chunked-prefill step (row i consumes its first seq_lens[i] columns;
    dt is zeroed past them, which makes padding an exact identity, and
    the carried state seeds the inter-chunk scan); with ``slot_ids`` a
    token-packed step (x is (1, P, D), per-token slot gather/scatter of
    the carried state); with neither, single-token decode.
    """
    cd = cfg.compute_dtype
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cd))
    z, xbc, dt, di, n, nh = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) positive rates

    if cache is None:
        xbc, _ = _causal_conv(xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
        xs, b, c = jnp.split(xbc, [di, di + n], axis=-1)
        xh = xs.reshape(*xs.shape[:2], nh, cfg.ssm_head_dim)
        s = xh.shape[1]
        pad = (-s) % cfg.ssm_chunk  # tail pad: dt=0 => identity decay, no update
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        else:
            dt_p, b_p, c_p = dt, b, c
        y, _ = _ssd_chunked(
            xh.astype(jnp.float32), dt_p, a,
            b_p.astype(jnp.float32), c_p.astype(jnp.float32), cfg.ssm_chunk,
        )
        if pad:
            y = y[:, :s]
            xh = xh[:, :s]
        new_cache = None
    elif seq_lens is not None:
        bs, s = xbc.shape[:2]
        k = cfg.ssm_conv
        valid = jnp.arange(s)[None, :] < seq_lens[:, None]  # (B, S)
        dt = jnp.where(valid[..., None], dt, 0.0)
        conv_out, _ = _causal_conv(
            xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd), cache["conv"]
        )
        xp = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        conv_state = chunked_conv_state(xp, seq_lens, k).astype(cache["conv"].dtype)
        xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
        xh = xs.reshape(bs, s, nh, cfg.ssm_head_dim)
        pad = (-s) % cfg.ssm_chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, b_p, c_p = xh, dt, b, c
        y, final = _ssd_chunked(
            xh_p.astype(jnp.float32), dt_p, a,
            b_p.astype(jnp.float32), c_p.astype(jnp.float32), cfg.ssm_chunk,
            init_state=cache["state"],
        )
        y = y[:, :s]
        new_cache = {"conv": conv_state, "state": final}
    elif slot_ids is not None:
        from ..kernels import ops as kops

        num_slots = cache["state"].shape[0]
        info = segment_info(slot_ids, num_slots)
        xbc1 = xbc[0]  # (P, C): packed steps carry batch dim 1
        dtp = jnp.where(info.valid[:, None], dt[0], 0.0)  # (P, H)
        conv_out, conv_state = packed_conv(
            xbc1, p["conv_w"].astype(cd), p["conv_b"].astype(cd),
            cache["conv"], info,
        )
        conv_out = jax.nn.silu(conv_out)
        xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
        xh1 = xs.reshape(-1, nh, cfg.ssm_head_dim).astype(jnp.float32)
        bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)
        da = dtp * a[None, :]
        cum = jnp.cumsum(da, axis=0)
        y1 = kops.ssd_segment(xh1, dtp, cum, bf, cf, slot_ids)
        # carried-state injection + segment-final write-back
        ent, w_end = final_segment_decay(cum, da, info)
        init = cache["state"][info.safe_slot]  # (P, H, N, hd)
        y1 = y1 + jnp.einsum("tn,thnp,th->thp", cf, init, jnp.exp(-ent))
        upd = jnp.einsum("tn,th,thp->thnp", bf, w_end * dtp, xh1)
        contrib = jnp.zeros_like(cache["state"]).at[info.write_slot].add(
            upd, mode="drop"
        )
        df = jnp.ones((num_slots, nh), jnp.float32).at[info.last_slot].set(
            jnp.exp(-ent), mode="drop"
        )
        state = cache["state"] * df[..., None, None] + contrib
        new_cache = {"conv": conv_state, "state": state}
        y = y1[None]
        xh = xh1[None]
    else:
        conv_out, conv_state = _causal_conv(
            xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd), cache["conv"]
        )
        xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
        xh = xs.reshape(xs.shape[0], 1, nh, cfg.ssm_head_dim).astype(jnp.float32)
        bf = b.astype(jnp.float32)[:, 0]
        cf = c.astype(jnp.float32)[:, 0]
        dt1 = dt[:, 0]  # (B, H)
        decay = jnp.exp(-dt1 * a)  # (B, H)
        # state: (B, H, N, P)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bf, dt1, xh[:, 0])
        state = cache["state"] * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cf, state)[:, None]  # (B,1,H,P)
        new_cache = {"conv": conv_state, "state": state}

    y = y + xh * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*y.shape[:2], di)
    # gated RMSNorm (Mamba-2 places the norm after gating by z)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y * p["norm_scale"].astype(jnp.float32)).astype(cd)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd)), new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), cfg.compute_dtype),
        "state": jnp.zeros((batch, nh, n, cfg.ssm_head_dim), jnp.float32),
    }
