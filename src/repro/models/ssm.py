"""Mamba-2 / SSD (state-space duality) block  [arXiv:2405.21060].

TPU-native adaptation of the SSD algorithm: the sequence is split into
chunks; within a chunk the recurrence is materialized as a (masked,
decay-weighted) attention-like matmul that feeds the MXU, and across
chunks a small recurrence over per-chunk states runs as a ``lax.scan``
(chunk count is seq/chunk, so the serial dimension is short).  This is the
standard SSD decomposition — quadratic-in-chunk, linear-in-sequence.

Decode maintains the SSM state (B, H, P, N) and a causal-conv ring state,
giving O(1) per-token cost (the reason mamba2 runs the ``long_500k``
shape).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def init_ssd(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ks = jax.random.split(rng, 4)
    conv_ch = di + 2 * n
    return {
        # in_proj packs [z (gate), x, B, C, dt] like the reference impl.
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + nh), dtype=cfg.params_dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_ch), in_axis=0, dtype=cfg.params_dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.params_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(cfg.params_dtype),
        "dt_bias": jnp.zeros((nh,), cfg.params_dtype),
        "d_skip": jnp.ones((nh,), cfg.params_dtype),
        "norm_scale": jnp.ones((di,), cfg.params_dtype),
        "w_out": dense_init(ks[3], (di, d), dtype=cfg.params_dtype),
    }


def _split_proj(cfg: ModelConfig, proj):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt, di, n, nh


def _causal_conv(xbc, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d; state=(B, K-1, C) carries decode history."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)
    else:
        xp = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
    out = sum(xp[:, i : xp.shape[1] - (k - 1) + i] * w[i] for i in range(k))
    out = jax.nn.silu(out + b)
    new_state = xp[:, -(k - 1) :] if k > 1 else None
    return out, new_state


def _ssd_chunked(x, dt, a, b, c, chunk: int):
    """Chunked SSD scan.

    x: (B, S, H, P)   dt: (B, S, H)   a: (H,) positive decay rates
    b, c: (B, S, N)   (single group, shared across heads — Mamba-2 default)
    Returns y: (B, S, H, P).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    nc = s // chunk
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = b.reshape(bs, nc, chunk, n)
    cc = c.reshape(bs, nc, chunk, n)

    da = dtc * a  # (B, nc, L, H): -dt*a is the log decay per step
    cum = jnp.cumsum(da, axis=2)  # cumulative log-decay within chunk

    # ---- intra-chunk (quadratic in chunk length; MXU-friendly) ----
    # decay(i, j) = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L,L,H)
    li = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask the exponent BEFORE exp: exp of the (positive) upper-triangle
    # values overflows and poisons the backward through where's 0*inf.
    decay = jnp.exp(-jnp.where(li, diff, 0.0)) * li
    scores = jnp.einsum("bgin,bgjn->bgij", cc, bc)  # (B,nc,L,L)
    att = scores[..., None] * decay * dtc[:, :, None, :, :]  # (B,nc,i,j,H)
    y_intra = jnp.einsum("bgijh,bgjhp->bgihp", att, xc)

    # ---- chunk states ----
    tail = cum[:, :, -1:, :] - cum  # decay from step j to chunk end
    wj = jnp.exp(-tail) * dtc  # (B,nc,L,H)
    states = jnp.einsum("bgjn,bgjh,bgjhp->bghnp", bc, wj, xc)  # (B,nc,H,N,P)

    # ---- inter-chunk recurrence over nc chunks ----
    chunk_decay = jnp.exp(-cum[:, :, -1, :])  # (B,nc,H)

    def scan_body(carry, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros_like(states[:, 0])
    _, prev_states = jax.lax.scan(
        scan_body,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,N,P)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(-cum)  # decay from chunk start to step i
    y_inter = jnp.einsum(
        "bgin,bgih,bghnp->bgihp", cc, in_decay, prev_states
    )
    y = (y_intra + y_inter).reshape(bs, s, h, p)
    return y


def apply_ssd(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """One Mamba-2 block. x: (B, S, D). cache => single-token decode."""
    cd = cfg.compute_dtype
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cd))
    z, xbc, dt, di, n, nh = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) positive rates

    if cache is None:
        xbc, _ = _causal_conv(xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
        xs, b, c = jnp.split(xbc, [di, di + n], axis=-1)
        xh = xs.reshape(*xs.shape[:2], nh, cfg.ssm_head_dim)
        s = xh.shape[1]
        pad = (-s) % cfg.ssm_chunk  # tail pad: dt=0 => identity decay, no update
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        else:
            dt_p, b_p, c_p = dt, b, c
        y = _ssd_chunked(
            xh.astype(jnp.float32), dt_p, a,
            b_p.astype(jnp.float32), c_p.astype(jnp.float32), cfg.ssm_chunk,
        )
        if pad:
            y = y[:, :s]
            xh = xh[:, :s]
        new_cache = None
    else:
        conv_out, conv_state = _causal_conv(
            xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd), cache["conv"]
        )
        xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
        xh = xs.reshape(xs.shape[0], 1, nh, cfg.ssm_head_dim).astype(jnp.float32)
        bf = b.astype(jnp.float32)[:, 0]
        cf = c.astype(jnp.float32)[:, 0]
        dt1 = dt[:, 0]  # (B, H)
        decay = jnp.exp(-dt1 * a)  # (B, H)
        # state: (B, H, N, P)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bf, dt1, xh[:, 0])
        state = cache["state"] * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cf, state)[:, None]  # (B,1,H,P)
        new_cache = {"conv": conv_state, "state": state}

    y = y + xh * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*y.shape[:2], di)
    # gated RMSNorm (Mamba-2 places the norm after gating by z)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y * p["norm_scale"].astype(jnp.float32)).astype(cd)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd)), new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), cfg.compute_dtype),
        "state": jnp.zeros((batch, nh, n, cfg.ssm_head_dim), jnp.float32),
    }
