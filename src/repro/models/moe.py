"""Mixture-of-Experts layer with top-k token-choice routing.

Two dispatch implementations:

* ``sort`` (default) — sort-based capacity dispatch: the (token, k) choices
  are sorted by expert id and scattered into a fixed (E, C, d) buffer
  (C = capacity per expert).  Compute cost is E*C*d*ff ≈ top_k/E-active
  FLOPs times the capacity factor — the roofline-honest formulation.
  Overflowing tokens are dropped (their residual passes through), the
  standard capacity-based behaviour [GShard; Switch].
* ``dense`` — every expert runs on every token, combined by router probs.
  Exact (no capacity drops); used as the correctness oracle in tests and
  for tiny decode batches.

Expert weights carry an explicit leading expert axis that the sharding
rules map onto the mesh "model" axis => expert parallelism; the
scatter/gather around the expert compute is where the all-to-all lives.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def init_moe(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(rng, 4)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=cfg.params_dtype),
        "w_out": dense_init(ks[3], (e, f, d), in_axis=1, dtype=cfg.params_dtype),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[1], (e, d, f), in_axis=1, dtype=cfg.params_dtype)
        p["w_in"] = dense_init(ks[2], (e, d, f), in_axis=1, dtype=cfg.params_dtype)
    else:
        p["w_in"] = dense_init(ks[2], (e, d, f), in_axis=1, dtype=cfg.params_dtype)
    return p


def _router(p, x2d, cfg: ModelConfig):
    """x2d: (T, d). Returns (probs (T,k), ids (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize (Mixtral-style)
    # Load-balancing aux loss (Switch): E * sum_e f_e * P_e
    e = cfg.n_experts
    counts = jnp.zeros((e,)).at[top_i.reshape(-1)].add(1.0)
    f_e = counts / jnp.maximum(counts.sum(), 1.0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return top_p, top_i, aux


def _expert_ffn(p, xe, cfg: ModelConfig):
    """xe: (E, C, d) -> (E, C, d)."""
    cd = cfg.compute_dtype
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(cd))
        u = jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(cd))
        act = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(cd)))
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(cd))


_SEGMENT_TOKENS = 16384  # dispatch-buffer bound: ~seg*k/E*cf slots per expert


def apply_moe_sort(
    p, x: jnp.ndarray, cfg: ModelConfig, segment_tokens: int = _SEGMENT_TOKENS
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based capacity dispatch. x: (B, S, d) -> (y, aux_loss).

    Tokens are processed in segments of ~``segment_tokens`` via ``lax.map``
    so the (E, C, d) dispatch buffer stays a bounded transient even when
    the SPMD partitioner replicates it (GShard-style grouping).
    """
    b, s, d = x.shape
    t_total = b * s
    n_seg = 1
    if t_total > segment_tokens:
        n_seg = t_total // segment_tokens
        while t_total % n_seg:
            n_seg -= 1
    if n_seg > 1:
        xs = x.reshape(n_seg, t_total // n_seg, 1, d)
        ys, auxs = jax.lax.map(lambda xi: _moe_sort_once(p, xi, cfg), xs)
        return ys.reshape(b, s, d), jnp.mean(auxs)
    y, aux = _moe_sort_once(p, x.reshape(t_total, 1, d), cfg)
    return y.reshape(b, s, d), aux


def _moe_sort_once(p, x, cfg: ModelConfig, psum_axis=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    cd = cfg.compute_dtype
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    cap = max(int(t * k / e * cfg.capacity_factor), 1)

    x2d = x.reshape(t, d)
    top_p, top_i, aux = _router(p, x2d, cfg)

    flat_e = top_i.reshape(-1)  # (T*k,) expert id per choice
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)  # token per choice

    order = jnp.argsort(flat_e)  # stable sort by expert
    se = flat_e[order]
    st = flat_t[order]
    sp = flat_p[order]

    # Position of each choice within its expert's segment.
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    valid = pos < cap

    slot = jnp.where(valid, se * cap + pos, e * cap)  # overflow -> scratch row
    buf = jnp.zeros((e * cap + 1, d), cd)
    buf = buf.at[slot].set(x2d[st].astype(cd), mode="drop")
    ye = _expert_ffn(p, buf[: e * cap].reshape(e, cap, d), cfg)
    if psum_axis is not None:
        # expert hidden dim is tensor-parallel inside shard_map: the w_out
        # contraction produced partial sums — reduce across the model axis.
        ye = jax.lax.psum(ye, psum_axis)

    out_choice = ye.reshape(e * cap, d)[jnp.minimum(slot, e * cap - 1)]
    out_choice = out_choice * (valid & (slot < e * cap))[:, None].astype(cd)
    y2d = jnp.zeros((t, d), cd).at[st].add(out_choice * sp[:, None].astype(cd))
    return y2d, aux


def apply_moe_capacity(
    p, x: jnp.ndarray, cfg: ModelConfig, valid: jnp.ndarray = None
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Serving-step capacity dispatch: (y, aux_loss, expert_overflow).

    The engine's MoE path.  Same sort-by-expert permutation as ``sort``,
    with the serving contract made explicit:

    * ``capacity = ceil(cf * tokens * top_k / n_experts)`` (static Python
      ceil, clamped to [1, tokens]; ``cf = inf`` means no drops — the
      dense-oracle parity point);
    * ``valid`` masks padding/idle tokens (dense chunked steps pass
      ``seq_lens``-derived masks, packed steps ``slot_ids >= 0``): invalid
      tokens are routed to a phantom expert bucket so they consume **no
      capacity** — a step's drops can't depend on how much padding the
      compiled shape carries;
    * ``expert_overflow`` counts real routed (token, choice) pairs dropped
      past capacity — per-expert DropCompute tau accounting, mirrored into
      ``StepStats.expert_overflow`` by the engine.

    Dropped choices fall through the residual path (the block adds y to
    x, so a fully-dropped token passes through unchanged), the standard
    capacity behaviour [GShard; Switch].

    At ``cf = inf`` the output is **byte-identical** to
    ``apply_moe_dense`` (the engine's parity criterion): the dense
    combine's zero-weight expert terms are exact FMA no-ops, so its
    accumulation reduces to the routed terms in expert-ascending order —
    reproduced here by sorting each token's k choices by expert index and
    combining with the same einsum contraction.
    """
    import math

    cd = cfg.compute_dtype
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    cf = cfg.capacity_factor
    cap = t if math.isinf(cf) else min(max(math.ceil(t * k / e * cf), 1), t)

    x2d = x.reshape(t, d)
    top_p, top_i, aux = _router(p, x2d, cfg)
    valid_t = jnp.ones((t,), bool) if valid is None else valid.reshape(t)

    # invalid tokens route to phantom bucket e: they take no capacity
    flat_e = jnp.where(jnp.repeat(valid_t, k), top_i.reshape(-1), e)
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)  # stable: within-expert keeps token order
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]

    counts = jnp.zeros((e + 1,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    routed = se < e
    keep = routed & (pos < cap)
    overflow = jnp.sum(routed & ~keep)

    slot = jnp.where(keep, se * cap + pos, e * cap)
    buf = jnp.zeros((e * cap + 1, d), cd)
    buf = buf.at[slot].set(x2d[st].astype(cd), mode="drop")
    ye = _expert_ffn(p, buf[: e * cap].reshape(e, cap, d), cfg)

    # combine per token over its k choices, sorted ascending by expert —
    # the order (and einsum form) that bit-matches the dense oracle
    inv = jnp.zeros((t * k,), jnp.int32).at[order].set(
        jnp.arange(t * k, dtype=jnp.int32)
    )
    slot_tk = slot[inv].reshape(t, k)
    keep_tk = keep[inv].reshape(t, k)
    out_tk = ye.reshape(e * cap, d)[jnp.minimum(slot_tk, e * cap - 1)]
    out_tk = out_tk * keep_tk[..., None].astype(cd)
    w_tk = jnp.where(keep_tk, top_p, 0.0).astype(cd)
    ksort = jnp.argsort(top_i, axis=1)
    y2d = jnp.einsum(
        "tk,tkd->td",
        jnp.take_along_axis(w_tk, ksort, axis=1),
        jnp.take_along_axis(out_tk, ksort[..., None], axis=1),
    )
    return y2d.reshape(b, s, d), aux, overflow


def apply_moe_dense(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle: run all experts on all tokens, combine with router probs."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    top_p, top_i, aux = _router(p, x2d, cfg)
    # (T, E) combine weights
    comb = jnp.zeros((b * s, cfg.n_experts))
    comb = comb.at[jnp.arange(b * s)[:, None], top_i].add(top_p)
    ye = _expert_ffn(p, jnp.broadcast_to(x2d[None], (cfg.n_experts, b * s, d)), cfg)
    y2d = jnp.einsum("te,etd->td", comb.astype(cfg.compute_dtype), ye)
    return y2d.reshape(b, s, d), aux


def apply_moe_spmd(p, x: jnp.ndarray, cfg: ModelConfig, mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Distribution-aware MoE: shard_map, local dispatch, TP experts.

    Under plain GSPMD the global argsort/scatter of the dispatch forces the
    partitioner to all-gather the token axis — activations become
    batch-replicated through the whole layer scan (observed: 16x residual
    blowup and TB-scale collectives).  Instead:

      * the data axes are mapped: every data shard dispatches its OWN
        tokens (local top-k, local sort, local capacity) — decentralized,
        no cross-worker coordination, exactly like DropCompute itself;
      * d_model stays sharded on the model axis through the dispatch (the
        (E, C, d) buffers scatter only the local d-slice — 16x smaller),
        w_in contracts the d-slice with one psum, w_out emits the local
        d-slice directly.  Works for any expert count, including
        mixtral's 8 experts on 16-way TP.
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:  # newer jax
        from jax import shard_map  # type: ignore

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model" if "model" in mesh.axis_names else None
    while dp and x.shape[0] % _axes_size(mesh, dp) != 0:
        dp = dp[1:]
    dp_spec = dp if dp else None
    if tp is not None and x.shape[-1] % mesh.shape[tp] != 0:
        tp = None

    gated = cfg.act in ("swiglu", "geglu")
    # Two expert-TP factorizations — pick the one with less collective
    # volume (see EXPERIMENTS.md §Perf):
    #   d_psum (f < d, e.g. qwen3 f=1536): d-sharded contractions, psum the
    #     two (E,C,f) gate/up activations — volume ~ 2f per slot;
    #   ag_f  (f >= d, e.g. mixtral f=16384): all-gather the dispatched
    #     (E,C,d) tokens once, f-sharded experts (no gate/up psum), then
    #     reduce-scatter the (E,C,d) output — volume ~ 2d per slot.
    scheme = "ag_f" if cfg.expert_d_ff >= cfg.d_model else "d_psum"
    if scheme == "ag_f":
        w_specs = {
            "router": P(tp, None),
            "w_in": P(None, None, tp),
            "w_out": P(None, tp, None),
        }
        if gated:
            w_specs["w_gate"] = P(None, None, tp)
    else:
        w_specs = {
            "router": P(tp, None),
            "w_in": P(None, tp, None),
            "w_out": P(None, None, tp),
        }
        if gated:
            w_specs["w_gate"] = P(None, tp, None)

    def local_fn(p_local, xl):
        b, s, d = xl.shape
        y, aux = _moe_sort_local(p_local, xl.reshape(b * s, d), cfg, tp, scheme)
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(b, s, d), aux

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=({k: w_specs[k] for k in p}, P(dp_spec, None, tp)),
        out_specs=(P(dp_spec, None, tp), P()),
        check_rep=False,
    )
    return fn({k: p[k] for k in p}, x)


def _moe_sort_local(p, x2d, cfg: ModelConfig, tp, scheme: str = "d_psum"):
    """Per-device MoE body: local dispatch over the local d-slice.

    x2d: (T_local, d_local).  Router logits psum over tp (router weights
    are d-sharded); the dispatch scatters only the d-slice.  Expert TP per
    ``scheme``: "d_psum" contracts the d-slice with one psum per gate/up
    projection; "ag_f" all-gathers the dispatched slots to full d, runs
    f-sharded experts psum-free, and reduce-scatters the output back to
    the d-slice.
    """
    cd = cfg.compute_dtype
    t, d_local = x2d.shape
    k, e = cfg.top_k, cfg.n_experts
    cap = max(int(t * k / e * cfg.capacity_factor), 1)

    logits = jnp.einsum(
        "td,de->te", x2d.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    if tp is not None:
        logits = jax.lax.psum(logits, tp)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    counts = jnp.zeros((e,)).at[top_i.reshape(-1)].add(1.0)
    aux = e * jnp.sum((counts / jnp.maximum(counts.sum(), 1.0)) * jnp.mean(probs, axis=0))

    flat_e = top_i.reshape(-1)
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    seg_counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(seg_counts) - seg_counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    valid = pos < cap
    slot = jnp.where(valid, se * cap + pos, e * cap)

    buf = jnp.zeros((e * cap + 1, d_local), cd)
    buf = buf.at[slot].set(x2d[st].astype(cd), mode="drop")
    xe = buf[: e * cap].reshape(e, cap, d_local)

    if scheme == "ag_f" and tp is not None:
        # gather dispatched slots to full d once; f-sharded experts need no
        # gate/up psum; reduce-scatter the output back to the d-slice.
        xe = jax.lax.all_gather(xe, tp, axis=-1, tiled=True)
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(cd))
        if "w_gate" in p:
            u = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(cd))
            act = jax.nn.silu(u) if cfg.act == "swiglu" else jax.nn.gelu(u)
            h = act * g
        else:
            h = jax.nn.gelu(g)
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(cd))
        ye = jax.lax.psum_scatter(ye, tp, scatter_dimension=2, tiled=True)
    else:
        # --- d-slice contractions with psum, f full, d-slice out ---
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(cd))
        if tp is not None:
            g = jax.lax.psum(g, tp)
        if "w_gate" in p:
            u = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(cd))
            if tp is not None:
                u = jax.lax.psum(u, tp)
            act = jax.nn.silu(u) if cfg.act == "swiglu" else jax.nn.gelu(u)
            h = act * g
        else:
            h = jax.nn.gelu(g)
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(cd))

    out_choice = ye.reshape(e * cap, d_local)[jnp.minimum(slot, e * cap - 1)]
    out_choice = out_choice * (valid & (slot < e * cap))[:, None].astype(cd)
    y2d = jnp.zeros((t, d_local), cd).at[st].add(out_choice * sp[:, None].astype(cd))
    return y2d, aux


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def apply_moe(p, x, cfg: ModelConfig, impl: str = "sort", mesh=None):
    if impl == "dense":
        return apply_moe_dense(p, x, cfg)
    if impl == "spmd":
        if mesh is None:
            mesh = _current_mesh()
        if mesh is not None and mesh.devices.size > 1:
            return apply_moe_spmd(p, x, cfg, mesh)
        return apply_moe_sort(p, x, cfg)
    return apply_moe_sort(p, x, cfg)


def _current_mesh():
    """The mesh from the enclosing ``with mesh:`` context, if any."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None
