"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / VLM / audio (enc-dec)
backbones.  The per-layer block sequence is given by ``layer_pattern``, a
string repeated/truncated to ``n_layers``:

    'G' — global (full causal) attention block
    'L' — local (sliding-window) attention block
    'R' — RG-LRU recurrent block (Griffin / RecurrentGemma)
    'M' — Mamba-2 SSD block

Every concrete config lives in ``repro.configs.<id>`` with its citation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense|moe|ssm|hybrid|vlm|audio
    # Trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    # Attention
    layer_pattern: str = "G"
    sliding_window: int = 1024
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_qk_norm: bool = False
    logit_softcap: float = 0.0
    # Block/act/norm
    act: str = "swiglu"  # swiglu|geglu|gelu
    norm: str = "rmsnorm"  # rmsnorm|layernorm
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 2
    moe_d_ff: int = 0  # per-expert hidden; 0 -> d_ff
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # SSM (Mamba-2 / SSD  [arXiv:2405.21060])
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # RG-LRU (Griffin  [arXiv:2402.19427])
    rglru_expand: float = 1.5
    rglru_conv: int = 4
    # Encoder (audio enc-dec; the conv/mel frontend is a stub per spec)
    enc_layers: int = 0
    enc_seq: int = 1500
    # VLM prefix (the ViT encoder + projector is a stub per spec)
    prefix_len: int = 0
    # Numerics
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    # Positional scheme: rope|learned|none
    pos: str = "rope"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def pattern(self) -> str:
        """Per-layer block types, length n_layers."""
        p = (self.layer_pattern * (self.n_layers // len(self.layer_pattern) + 1))
        return p[: self.n_layers]

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact total parameter count (for 6ND roofline numbers).

        Computed by abstract evaluation of the real initializer (zero
        allocation) and cached — always consistent with the model code.
        """
        return _exact_param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        full_moe = self.n_experts * self._expert_params()
        active_moe = self.top_k * self._expert_params()
        return self.param_count() - len(self.pattern) * (full_moe - active_moe) // 1

    def _attn_params(self) -> int:
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.hd
        n = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.qkv_bias:
            n += (h + 2 * kv) * hd
        return n

    def _mlp_params(self, dff: int) -> int:
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        return mult * self.d_model * dff

    def _expert_params(self) -> int:
        return self._mlp_params(self.expert_d_ff)

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        norms = 2 * d
        if kind in ("G", "L"):
            mix = self._attn_params()
        elif kind == "R":
            dr = int(self.rglru_expand * d)
            # in/out proj x2 (gated), conv, rg-lru gates
            mix = 2 * d * dr + dr * d + self.rglru_conv * dr + 2 * dr * dr // 8 + 2 * dr
        elif kind == "M":
            di = self.ssm_expand * d
            nh = di // self.ssm_head_dim
            # in_proj -> [z, x, B, C, dt], conv over (x,B,C), out_proj
            mix = d * (2 * di + 2 * self.ssm_state + nh) + self.ssm_conv * (
                di + 2 * self.ssm_state
            ) + di * d + 2 * nh
        else:
            raise ValueError(kind)
        if self.n_experts > 0 and kind in ("G", "L"):
            ff = self.n_experts * self._expert_params() + d * self.n_experts
        else:
            ff = self._mlp_params(self.d_ff) if kind in ("G", "L") else self._mlp_params(self.d_ff)
        # SSM blocks in pure-SSM models have no separate MLP (Mamba-2 style)
        if kind == "M":
            ff = 0
            norms = d
        return mix + ff + norms

    def validate(self) -> "ModelConfig":
        # raised, never assert-ed: under python -O a bad config would
        # sail through here and fail as a shape error (or worse, a
        # silently-wrong reshape) deep inside a jitted trace
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(
                f"GQA group mismatch: n_heads={self.n_heads} is not a "
                f"multiple of n_kv_heads={self.n_kv_heads}"
            )
        if "M" in self.pattern:
            di = self.ssm_expand * self.d_model
            if di % self.ssm_head_dim != 0:
                raise ValueError(
                    f"SSD inner dim {di} (ssm_expand * d_model) is not a "
                    f"multiple of ssm_head_dim={self.ssm_head_dim}"
                )
        if self.n_experts and not 0 < self.top_k <= self.n_experts:
            raise ValueError(
                f"top_k={self.top_k} must be in [1, n_experts="
                f"{self.n_experts}]"
            )
        return self


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"
    # gradient accumulation micro-batches for train mode (DropCompute's M)
    microbatches: int = 8


import functools


@functools.lru_cache(maxsize=64)
def _exact_param_count(cfg: "ModelConfig") -> int:
    import jax  # local: keep config importable without tracing

    from . import model as _model  # lazy: avoids import cycle

    abs_params = jax.eval_shape(lambda: _model.init_params(jax.random.PRNGKey(0), cfg))
    n = 0
    for leaf in jax.tree.leaves(abs_params):
        k = 1
        for d in leaf.shape:
            k *= d
        n += k
    return n


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train", microbatches=8),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
