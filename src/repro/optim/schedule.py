"""Learning-rate schedules (warmup + linear/cosine decay, as in You et al.)."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp


def warmup_linear(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.0) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((total_steps - step) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        decay = floor + (peak_lr - floor) * frac
        return jnp.where(step < warmup_steps, warm, decay)

    return lr


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.0) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        decay = floor + 0.5 * (peak_lr - floor) * (1.0 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup_steps, warm, decay)

    return lr


def constant(lr_value: float) -> Callable:
    def lr(step):
        return jnp.asarray(lr_value, jnp.float32)

    return lr
