from .optimizers import (
    OPTIMIZERS,
    Optimizer,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    lamb,
    lans,
    make,
    sgd,
)
from .schedule import constant, warmup_cosine, warmup_linear

__all__ = [
    "OPTIMIZERS",
    "Optimizer",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "lamb",
    "lans",
    "make",
    "sgd",
    "constant",
    "warmup_cosine",
    "warmup_linear",
]
