"""Optimizers built from scratch in JAX (no optax in this environment).

Implements the optimizers the paper trains with:
  * LAMB  [You et al. 2019]      — BERT-Large generalization runs (§5.1)
  * LANS  [Zheng et al. 2020]    — BERT-1.5B runtime runs (§5.2 / B.1)
  * AdamW, SGD(+momentum)        — baselines / ResNet runs

API mirrors optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``.  All states are pytrees so they shard under pjit like
the parameters themselves (ZeRO-1/3 falls out of the sharding rules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


# ---------------------------------------------------------------------------


def sgd(lr: Callable | float, momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else lr
        mu = jax.tree.map(
            lambda m, g, p: momentum * m + g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32),
            state["mu"], grads, params,
        )
        upd = jax.tree.map(lambda m: -lr_t * m, mu)
        return upd, {"mu": mu, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------


def _adam_moments(grads, state, b1, b2):
    # math in fp32, storage in the state's dtype (bf16 state halves the
    # per-device optimizer bytes for >100B models on 16 GB chips)
    m = jax.tree.map(
        lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)),
        state["m"], grads,
    )
    v = jax.tree.map(
        lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))),
        state["v"], grads,
    )
    return m, v


def _store(moments, like):
    return jax.tree.map(lambda x, l: x.astype(l.dtype), moments, like)


def _moment_init(params, state_dtype=jnp.float32):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    state_dtype=jnp.float32,
) -> Optimizer:
    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else lr
        m, v = _adam_moments(grads, state, b1, b2)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def u(m_, v_, p):
            mh = m_ / bc1
            vh = v_ / bc2
            return -lr_t * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))

        upd = jax.tree.map(u, m, v, params)
        state = {"m": _store(m, state["m"]), "v": _store(v, state["v"]), "count": count}
        return upd, state

    return Optimizer(lambda p: _moment_init(p, state_dtype), update)


# ---------------------------------------------------------------------------


def _trust_ratio(p, u, min_norm: float = 1e-8):
    pn = jnp.linalg.norm(p.astype(jnp.float32).reshape(-1))
    un = jnp.linalg.norm(u.reshape(-1))
    ratio = jnp.where((pn > min_norm) & (un > min_norm), pn / un, 1.0)
    return ratio


def lamb(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
) -> Optimizer:
    """LAMB [You et al. 2019]: Adam direction rescaled by the layerwise
    trust ratio ||p|| / ||update||."""

    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else lr
        m, v = _adam_moments(grads, state, b1, b2)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def u(m_, v_, p):
            r = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return -lr_t * _trust_ratio(p, r) * r

        upd = jax.tree.map(u, m, v, params)
        return upd, {"m": m, "v": v, "count": count}

    return Optimizer(_moment_init, update)


def lans(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
) -> Optimizer:
    """LANS [Zheng et al. 2020]: Nesterov-style two-part LAMB with
    gradient normalization — the optimizer of the paper's BERT-1.5B runs."""

    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else lr
        # gradient normalization (per-layer)
        grads = jax.tree.map(
            lambda g: g.astype(jnp.float32)
            / (jnp.linalg.norm(g.astype(jnp.float32).reshape(-1)) + 1e-9),
            grads,
        )
        m, v = _adam_moments(grads, state, b1, b2)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def u(m_, v_, g, p):
            pf = p.astype(jnp.float32)
            denom = jnp.sqrt(v_ / bc2) + eps
            r_m = (m_ / bc1) / denom + weight_decay * pf
            r_g = g / denom + weight_decay * pf
            return -lr_t * (
                b1 * _trust_ratio(p, r_m) * r_m + (1 - b1) * _trust_ratio(p, r_g) * r_g
            )

        upd = jax.tree.map(u, m, v, grads, params)
        return upd, {"m": m, "v": v, "count": count}

    return Optimizer(_moment_init, update)


OPTIMIZERS = {"sgd": sgd, "adamw": adamw, "lamb": lamb, "lans": lans}


def make(name: str, lr, **kw) -> Optimizer:
    return OPTIMIZERS[name](lr, **kw)
