"""Figure 12: DropCompute on top of Local-SGD in straggler environments."""
from __future__ import annotations

from repro.core.local_sgd import StragglerScenario, localsgd_speedup

from .common import write_rows


def run(quick: bool = True):
    iters = 200 if quick else 1000
    rows = []
    for mode in ("uniform", "single_server"):
        sc = StragglerScenario(mode=mode, p=0.04 if mode == "uniform" else 0.3,
                               delay=1.0, base=0.1, server_size=4)
        for h in (1, 2, 4, 8, 16):
            s_plain, _ = localsgd_speedup(sc, 32, h, iters=iters)
            tau = h * 0.1 * 1.6
            s_drop, drop = localsgd_speedup(sc, 32, h, tau=tau, iters=iters)
            rows.append({"scenario": mode, "sync_period": h,
                         "localsgd_speedup": s_plain,
                         "with_dropcompute": s_drop, "drop_rate": drop})
    write_rows("fig12_localsgd", rows)

    u8 = [r for r in rows if r["scenario"] == "uniform" and r["sync_period"] == 8][0]
    s8 = [r for r in rows if r["scenario"] == "single_server" and r["sync_period"] == 8][0]
    return [
        {"name": "fig12/uniform_h8_localsgd", "value": round(u8["localsgd_speedup"], 3)},
        {"name": "fig12/uniform_h8_dropcompute", "value": round(u8["with_dropcompute"], 3)},
        {"name": "fig12/single_server_h8_localsgd", "value": round(s8["localsgd_speedup"], 3)},
        {"name": "fig12/single_server_h8_dropcompute", "value": round(s8["with_dropcompute"], 3)},
    ]
