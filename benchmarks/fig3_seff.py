"""Figure 3: S_eff(tau) — simulation vs analytic vs analytic-given-E[T].

(a) normal micro-batch latency: all three curves agree;
(b) paper-lognormal latency: the pure-Gaussian analytic drifts, plugging
    the empirical E[T] fixes it (appendix C.2's point);
(c) the optimal threshold trade-off (completion rate vs step speedup).
"""
from __future__ import annotations

import numpy as np

from repro.core import LatencyModel, NoiseModel, effective_speedup, simulate
from repro.core.threshold import select_threshold

from .common import write_rows

M = 12
N = 64
TC = 0.5


def _curves(model, iters, tag):
    sim = simulate(model, iters, N, M, tc=TC, seed=1)
    mu, sig = model.mean, model.std
    e_t_emp = float(sim.T.mean())
    grid = np.linspace(M * mu * 0.7, float(sim.T.max()) * 1.02, 60)
    rows = []
    for tau in grid:
        t_iter, frac = sim.with_threshold(tau)
        rows.append({
            "panel": tag, "tau": float(tau),
            "simulation": sim.effective_speedup(tau),
            "analytic": effective_speedup(tau, mu, sig, M, N, TC),
            "analytic_given_ET": effective_speedup(tau, mu, sig, M, N, TC, e_t=e_t_emp),
            "completion": float(frac.mean()),
            "step_speedup": float(((sim.T + TC) / t_iter).mean()),
        })
    return rows, sim


def run(quick: bool = True):
    iters = 100 if quick else 400
    rows_a, _ = _curves(
        LatencyModel(base=0.45, noise=NoiseModel(kind="normal", mean=0.5, var=0.05)),
        iters, "a_normal",
    )
    rows_b, sim_b = _curves(
        LatencyModel(base=0.45, noise=NoiseModel(kind="paper_lognormal")), iters, "b_lognormal"
    )
    write_rows("fig3_seff", rows_a + rows_b)

    # panel (c): automatic tau*
    res = select_threshold(sim_b.t, TC)

    # agreement metrics: max |analytic - simulation| over the curve
    def max_err(rows, key):
        return max(abs(r[key] - r["simulation"]) for r in rows)

    return [
        {"name": "fig3a/max_err_analytic_vs_sim", "value": round(max_err(rows_a, "analytic"), 4)},
        {"name": "fig3b/max_err_analytic_vs_sim", "value": round(max_err(rows_b, "analytic"), 4)},
        {"name": "fig3b/max_err_givenET_vs_sim", "value": round(max_err(rows_b, "analytic_given_ET"), 4)},
        {"name": "fig3c/tau_star", "value": round(res.tau, 4)},
        {"name": "fig3c/seff_at_tau_star", "value": round(res.speedup, 4)},
    ]
