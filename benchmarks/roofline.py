"""Roofline analysis from the multi-pod dry-run artifacts (deliverable g).

For each (arch x shape x mesh) JSON produced by ``repro.launch.dryrun``:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s      [s]
    memory term     = HLO_bytes_per_device / HBM_bw           [s]
    collective term = collective_bytes_per_device / link_bw   [s]

(the walker's numbers are per-device, so the /chips in the spec formulas
is already applied).  Also reports MODEL_FLOPS = 6*N*D (train; 2*N*D
prefill / 2*N_active*B decode) vs HLO_FLOPs, the dominant term, and a
one-line diagnosis.  Emits CSV + a markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from .common import RESULTS, write_rows

DRYRUN = RESULTS / "dryrun"

PEAK = 197e12
HBM = 819e9
LINK = 50e9


def model_flops_per_device(rec: Dict) -> float:
    n_active = rec["active_param_count"]
    chips = rec["n_chips"]
    shape = rec["shape"]
    if rec["mode"] == "train":
        tokens = {"train_4k": 256 * 4096}[shape]
        return 6.0 * n_active * tokens / chips
    if rec["mode"] == "prefill":
        tokens = {"prefill_32k": 32 * 32768}[shape]
        return 2.0 * n_active * tokens / chips
    tokens = {"decode_32k": 128, "long_500k": 1}[shape]
    return 2.0 * n_active * tokens / chips


def analyze_record(rec: Dict) -> Dict:
    w = rec["walked"]
    comp = w["flops"] / PEAK
    mem = w["bytes"] / HBM
    # prefer the TPU-native collective estimate (CPU float-normalization
    # compiles all collectives as f32) when the walker recorded one
    coll = w.get("collective_bytes_tpu", w["collective_bytes"]) / LINK
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    useful = mf / max(w["flops"], 1.0)

    hints = {
        "compute": "increase arithmetic intensity (larger tiles / fuse pointwise chains into the matmuls)",
        "memory": "cut HBM traffic: lower-precision residuals/weights at use, fuse reads, larger microbatches",
        "collective": "reduce FSDP regathering (bf16 gathers, fewer/larger microbatches) or overlap collectives with compute",
    }
    step_time = max(terms.values())
    mfu = mf / PEAK / step_time if step_time > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops": w["flops"],
        "useful_ratio": useful,
        "roofline_mfu": mfu,
        "mem_gib_per_dev": (rec["memory"]["temp_bytes"] + rec["memory"]["argument_bytes"]) / 2**30,
        "hint": hints[dominant],
    }


def load_records(tag: str = "") -> List[Dict]:
    recs = []
    for p in sorted(DRYRUN.glob(f"*{tag}.json")):
        if tag == "" and "__" in p.stem:
            continue  # skip perf-iteration tagged variants in the base table
        recs.append(json.loads(p.read_text()))
    return recs


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | dominant "
           "| useful (6ND/HLO) | roofline-MFU | mem GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_mfu']:.3f} | {r['mem_gib_per_dev']:.1f} |\n"
        )
    return hdr + body


def run(quick: bool = True):
    recs = load_records()
    if not recs:
        return [{"name": "roofline/records", "value": 0}]
    rows = [analyze_record(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    write_rows("roofline", rows)
    (RESULTS / "roofline" / "table.md").write_text(markdown_table(rows))

    single = [r for r in rows if r["mesh"] == "16x16"]
    by_dom = {}
    for r in single:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    worst = min(single, key=lambda r: r["roofline_mfu"]) if single else None
    out = [
        {"name": "roofline/records", "value": len(rows)},
        {"name": "roofline/dominant_counts", "value": json.dumps(by_dom)},
    ]
    if worst:
        out.append({"name": "roofline/worst_mfu_pair",
                    "value": f"{worst['arch']}x{worst['shape']}={worst['roofline_mfu']:.4f}"})
    return out
