"""Chunked-prefill serving throughput vs the token-streaming baseline.

    PYTHONPATH=src python benchmarks/serve_throughput.py

Prompt-heavy workload (long prompts, few output tokens) through
``ContinuousBatcher``, sweeping prefill chunk size and the per-step token
budget.  ``chunk=1`` IS the seed token-streaming scheduler (one prompt
token per slot per engine step); every other row must produce
token-identical outputs while reaching first tokens much faster.

Reported metric: prefill-phase throughput = total prompt tokens / wall
time until every admitted request has emitted its first token.  Engines
are warmed up (one throwaway workload) so the sweep measures steady-state
scheduling, not XLA compilation.
"""
import argparse
import time

import jax
import numpy as np

from repro.models import ModelConfig
from repro.models.model import init_params
from repro.serve import ContinuousBatcher, Request


def make_requests(n, prompt_len, new_tokens, vocab, seed=1):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i, prompt=rng.integers(0, vocab, size=prompt_len).tolist(),
                max_new_tokens=new_tokens)
        for i in range(n)
    ]


def run_once(eng, requests):
    for r in requests:
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    total = time.perf_counter() - t0
    prefill_wall = max(r.first_token_at for r in done.values()) - t0
    return done, prefill_wall, total


def bench(params, cfg, args, chunk, budget):
    eng = ContinuousBatcher(
        params, cfg, batch_slots=args.batch, max_len=args.prompt_len + args.new_tokens,
        chunk_size=chunk, token_budget=budget,
    )
    # warmup: compile both step programs on a throwaway workload
    warm = make_requests(args.batch, args.prompt_len, 2, cfg.vocab_size, seed=7)
    run_once(eng, warm)
    eng.reset_stats()

    reqs = make_requests(args.requests, args.prompt_len, args.new_tokens, cfg.vocab_size)
    done, prefill_wall, total = run_once(eng, reqs)
    outputs = {u: r.output for u, r in done.items()}
    n_prompt = sum(len(r.prompt) for r in reqs)
    s = eng.stats_summary()
    return {
        "chunk": chunk,
        "budget": budget,
        "prefill_tok_s": n_prompt / prefill_wall,
        "total_s": total,
        "steps": eng.steps,
        "max_step_tokens": s["max_step_tokens"],
        "mean_ttft_ms": s["mean_ttft"] * 1e3,
        "outputs": outputs,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--chunks", type=int, nargs="+", default=[4, 16, 32])
    ap.add_argument("--budgets", type=int, nargs="+", default=[0, 64],
                    help="0 = uncapped")
    args = ap.parse_args()

    cfg = ModelConfig(name="serve-bench", n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab_size=1003, sliding_window=64,
                      layer_pattern="LG", dtype="float32", remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.requests} requests x {args.prompt_len}-token prompts, "
          f"{args.batch} slots")

    base = bench(params, cfg, args, chunk=1, budget=None)
    rows = [base]
    for chunk in args.chunks:
        for b in args.budgets:
            rows.append(bench(params, cfg, args, chunk, b or None))

    hdr = f"{'chunk':>6} {'budget':>7} {'prefill tok/s':>14} {'speedup':>8} " \
          f"{'steps':>6} {'max step tok':>13} {'mean TTFT ms':>13} {'outputs':>8}"
    print(hdr)
    print("-" * len(hdr))
    ok = True
    for r in rows:
        same = r["outputs"] == base["outputs"]
        ok &= same
        print(f"{r['chunk']:>6} {str(r['budget'] or '-'):>7} "
              f"{r['prefill_tok_s']:>14.1f} {r['prefill_tok_s']/base['prefill_tok_s']:>7.2f}x "
              f"{r['steps']:>6} {r['max_step_tokens']:>13.0f} "
              f"{r['mean_ttft_ms']:>13.1f} {'same' if same else 'DIFF':>8}")

    best = max(rows[1:], key=lambda r: r["prefill_tok_s"])
    speedup = best["prefill_tok_s"] / base["prefill_tok_s"]
    print(f"\nbest chunked config: chunk={best['chunk']} budget={best['budget']} "
          f"-> {speedup:.1f}x prefill throughput vs token streaming")
    if not ok:
        raise SystemExit("FAIL: chunked outputs diverged from the streaming baseline")
    if speedup < 5.0:
        raise SystemExit(f"FAIL: expected >=5x prefill speedup, got {speedup:.2f}x")
    print("PASS: outputs identical, >=5x prefill-phase speedup")


if __name__ == "__main__":
    main()
