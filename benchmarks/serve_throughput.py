"""Chunked-prefill serving throughput vs the token-streaming baseline.

    PYTHONPATH=src python benchmarks/serve_throughput.py

Prompt-heavy workload (long prompts, few output tokens) through
``ContinuousBatcher``, sweeping prefill chunk size and the per-step token
budget.  ``chunk=1`` IS the seed token-streaming scheduler (one prompt
token per slot per engine step); every other row must produce
token-identical outputs while reaching first tokens much faster.

Reported metric: prefill-phase throughput = total prompt tokens / wall
time until every admitted request has emitted its first token.  Engines
are warmed up (one throwaway workload) so the sweep measures steady-state
scheduling, not XLA compilation.

``--packed`` runs the mode A/B instead: dense, token-packed, and
paged-KV engines on the same mixed trace per budget, asserting identical
outputs and reporting mixed-step wall time — the packed program's
compiled shape is the packed capacity, so mean step wall must *scale
with granted tokens* (measurably lower at token_budget=4 than the dense
mixed step, which always computes the full (B, chunk_size) shape).  The
paged rows add cache-byte and page-usage accounting, plus a
prefix-sharing record (second request with a shared 256-token prefix:
fewer prefill steps, fewer pool pages).

``--spec`` adds the speculative-decoding rows: a "spec" engine (paged +
packed + n-gram proposer) joins the per-budget A/B — outputs must stay
token-identical to the dense oracle, acceptance does the quality control
— and a dedicated repetitive-prompt record pins the decode-side win:
greedy decode of self-repeating streams is n-gram territory, so the spec
engine must take >= 1.5x fewer engine steps per generated token than the
same engine without speculation.

The A/B also carries **stochastic-sampling rows** (temperature=0.8,
top_p=0.95, per-request seeds): "sampled-dense" is the sampled oracle,
"sampled" (packed+paged) and — with ``--spec`` — "spec-sampled"
(rejection-sampling speculation) must be byte-identical to it; the
spec-sampled row records the acceptance rate under sampling so the
greedy-vs-sampled throughput and acceptance trajectory is tracked in
``BENCH_serve.json`` across PRs.

``--json PATH`` additionally writes every row as a machine-readable perf
record (the CI full lane emits ``BENCH_serve.json``), so the repo keeps a
benchmark trajectory across PRs.
"""
import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import make_requests, mixed_requests  # noqa: E402

from repro.models import ModelConfig
from repro.models.model import init_params
from repro.serve import (
    ContinuousBatcher,
    NGramProposer,
    Request,
    SamplingParams,
    SpecConfig,
)

SPEC_K = 4

#: stochastic rows decode at temperature 0.8 with nucleus 0.95; request
#: ``i`` streams from seed ``SAMPLED.seed + i`` (see common._req_sampling)
SAMPLED = SamplingParams(temperature=0.8, top_p=0.95)

#: per A/B mode: (engine-kwargs factory, request sampling params).  paged
#: rides the packed step program (the two compose) so its delta against
#: "packed" isolates the page tables, and "spec" rides paged so its delta
#: isolates the propose/verify loop.  The sampled trio replays the same
#: trace stochastically: "sampled-dense" is the sampled oracle,
#: "sampled" (packed+paged) and "spec-sampled" (rejection-sampling
#: speculation) must reproduce it byte-identically.  Kwargs are
#: factories: the spec proposer keeps per-slot state, so every engine
#: needs a fresh one.
MODES = {
    "dense": (lambda: {}, None),
    "packed": (lambda: {"packed": True}, None),
    "paged": (lambda: {"packed": True, "cache": "paged", "page_size": 16},
              None),
    "paged-int8": (lambda: {"packed": True, "cache": "paged",
                            "page_size": 16, "kv_dtype": "int8"}, None),
    "spec": (lambda: {"packed": True, "cache": "paged", "page_size": 16,
                      "spec": SpecConfig(NGramProposer(), k=SPEC_K)}, None),
    "sampled-dense": (lambda: {}, SAMPLED),
    "sampled": (lambda: {"packed": True, "cache": "paged", "page_size": 16},
                SAMPLED),
    "spec-sampled": (lambda: {"packed": True, "cache": "paged",
                              "page_size": 16,
                              "spec": SpecConfig(NGramProposer(),
                                                 k=SPEC_K)}, SAMPLED),
}

#: mode -> oracle whose outputs it must reproduce *bit-identically*
#: (greedy modes against "dense", sampled modes against "sampled-dense").
#: paged-int8 quantizes KV rows, so it gets a token-match-rate tier
#: instead (lengths must match; >= INT8_MATCH_MIN of tokens identical).
ORACLE = {
    "packed": "dense",
    "paged": "dense",
    "spec": "dense",
    "sampled": "sampled-dense",
    "spec-sampled": "sampled-dense",
}
INT8_MATCH_MIN = 0.9


def token_match(outputs, oracle):
    """(fraction of positions with identical tokens, all stream lengths equal)."""
    lens_ok = (set(outputs) == set(oracle)
               and all(len(outputs[u]) == len(oracle[u]) for u in oracle))
    total = sum(len(v) for v in oracle.values())
    same = sum(a == b for u in oracle if u in outputs
               for a, b in zip(outputs[u], oracle[u]))
    return (same / total if total else 1.0), lens_ok


def cache_stats(eng):
    """Allocated cache bytes + page accounting for one engine."""
    if eng.kv is not None:
        return {
            "cache_bytes": eng.kv.memory_bytes(),
            "num_pages": eng.kv.num_pages,
            "peak_used_pages": int(eng.stats_summary()["peak_used_pages"]),
            "touched_pages": eng.kv.tables.touched_pages,
        }
    leaves = jax.tree_util.tree_leaves(eng.cache)
    return {"cache_bytes": int(sum(x.nbytes for x in leaves))}


def run_once(eng, requests):
    for r in requests:
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    total = time.perf_counter() - t0
    prefill_wall = max(r.first_token_at for r in done.values()) - t0
    return done, prefill_wall, total


def bench(params, cfg, args, chunk, budget):
    eng = ContinuousBatcher(
        params, cfg, batch_slots=args.batch, max_len=args.prompt_len + args.new_tokens,
        chunk_size=chunk, token_budget=budget,
    )
    # warmup: compile both step programs on a throwaway workload
    warm = make_requests(args.batch, args.prompt_len, 2, cfg.vocab_size, seed=7)
    run_once(eng, warm)
    eng.reset_stats()

    reqs = make_requests(args.requests, args.prompt_len, args.new_tokens, cfg.vocab_size)
    done, prefill_wall, total = run_once(eng, reqs)
    outputs = {u: r.output for u, r in done.items()}
    n_prompt = sum(len(r.prompt) for r in reqs)
    s = eng.stats_summary()
    return {
        "chunk": chunk,
        "budget": budget,
        "prefill_tok_s": n_prompt / prefill_wall,
        "total_s": total,
        "steps": eng.steps,
        "max_step_tokens": s["max_step_tokens"],
        "mean_ttft_ms": s["mean_ttft"] * 1e3,
        "outputs": outputs,
    }


def mixed_trace(args, vocab, seed=1, sampling=None):
    """Seeded long/short trace (see ``common.mixed_requests``)."""
    return mixed_requests(args.requests, args.prompt_len, args.new_tokens,
                          vocab, seed=seed, sampling=sampling)


def bench_modes_ab(params, cfg, args):
    """Dense vs packed vs paged (vs spec) A/B on the same trace per
    budget.  Returns the machine-readable rows for ``--json``."""
    budgets = [b or None for b in args.budgets]
    if 4 not in budgets:
        budgets = [4] + budgets  # the acceptance point: budget=4
    modes = dict(MODES) if args.spec else {
        m: f for m, f in MODES.items() if m not in ("spec", "spec-sampled")
    }

    hdr = f"{'budget':>7} {'mode':>13} {'granted/step':>13} {'mixed-step ms':>14} " \
          f"{'decode-step ms':>15} {'TTFT ms':>8} {'tok/s':>8} {'cache MiB':>10} {'outputs':>8}"
    print(hdr)
    print("-" * len(hdr))
    rows, records = {}, []
    for budget in budgets:
        for mode, (mode_kw_fn, mode_sampling) in modes.items():
            eng = ContinuousBatcher(
                params, cfg, batch_slots=args.batch,
                max_len=args.prompt_len + args.new_tokens,
                chunk_size=16, token_budget=budget, **mode_kw_fn(),
            )
            run_once(eng, mixed_trace(args, cfg.vocab_size, seed=7,
                                      sampling=mode_sampling))  # warmup
            # reset_stats rebaselines the page accounting too
            # (KVCache.reset_accounting), so the measured run records only
            # its own page traffic — no engine rebuild needed
            eng.reset_stats()
            done, _, total = run_once(
                eng, mixed_trace(args, cfg.vocab_size,
                                 sampling=mode_sampling))
            mixed = [s for s in eng.step_stats if s.prefill_tokens > 0]
            decode = [s for s in eng.step_stats if s.prefill_tokens == 0]
            mixed_ms = 1e3 * float(np.mean([s.wall_time for s in mixed]))
            decode_ms = 1e3 * float(np.mean([s.wall_time for s in decode])) if decode else float("nan")
            granted = float(np.mean([s.scheduled_tokens for s in mixed]))
            summ = eng.stats_summary()
            n_tok = sum(len(r.prompt) + len(r.output) for r in done.values())
            cstats = cache_stats(eng)
            rows[(budget, mode)] = {
                "mixed_ms": mixed_ms,
                "outputs": {u: r.output for u, r in done.items()},
            }

            spec_stats = (
                {"acceptance_rate": summ["acceptance_rate"],
                 "draft_tokens": summ["draft_tokens"]}
                if mode in ("spec", "spec-sampled") else {}
            )
            sampling_rec = (
                {"sampling": {"temperature": mode_sampling.temperature,
                              "top_k": mode_sampling.top_k,
                              "top_p": mode_sampling.top_p}}
                if mode_sampling is not None else {}
            )
            records.append({
                "mode": mode, "budget": budget, "granted_per_step": granted,
                "mixed_step_ms": mixed_ms, "decode_step_ms": decode_ms,
                "mean_ttft_ms": summ["mean_ttft"] * 1e3,
                "p99_ttft_ms": summ["p99_ttft"] * 1e3,
                "tokens_per_s": n_tok / total, "total_s": total,
                "steps": eng.steps,
                "steps_per_token": summ["steps_per_token"],
                **spec_stats, **sampling_rec, **cstats,
            })
            if mode in ("dense", "sampled-dense"):
                verdict = "oracle"
            elif mode in ORACLE:
                verdict = "same" if (
                    rows[(budget, mode)]["outputs"]
                    == rows[(budget, ORACLE[mode])]["outputs"]
                ) else "DIFF"
            else:
                frac, lens_ok = token_match(
                    rows[(budget, mode)]["outputs"], rows[(budget, "dense")]["outputs"]
                )
                verdict = f"{frac:.0%}" if lens_ok else "LEN-DIFF"
                records[-1]["token_match"] = frac
            print(f"{str(budget or '-'):>7} {mode:>13} "
                  f"{granted:>13.1f} {mixed_ms:>14.2f} {decode_ms:>15.2f} "
                  f"{summ['mean_ttft'] * 1e3:>8.1f} {n_tok / total:>8.0f} "
                  f"{cstats['cache_bytes'] / 2**20:>10.2f} {verdict:>8}")

    for b in budgets:
        for mode in modes:
            if mode in ("dense", "sampled-dense"):
                continue
            if mode in ORACLE:
                if rows[(b, mode)]["outputs"] != rows[(b, ORACLE[mode])]["outputs"]:
                    raise SystemExit(
                        f"FAIL: {mode} outputs diverged from the "
                        f"{ORACLE[mode]} oracle at budget={b}"
                    )
            else:
                frac, lens_ok = token_match(
                    rows[(b, mode)]["outputs"], rows[(b, "dense")]["outputs"]
                )
                if not lens_ok or frac < INT8_MATCH_MIN:
                    raise SystemExit(
                        f"FAIL: {mode} token match {frac:.0%} "
                        f"(lens_ok={lens_ok}) below {INT8_MATCH_MIN:.0%} "
                        f"at budget={b}"
                    )
    # sampled streams must actually be stochastic, not greedy in disguise
    for b in budgets:
        if rows[(b, "sampled-dense")]["outputs"] == rows[(b, "dense")]["outputs"]:
            raise SystemExit(
                f"FAIL: sampled outputs identical to greedy at budget={b} "
                f"(sampling params not threaded through?)"
            )

    # proportionality: packed mixed-step wall scales with granted tokens
    caps = sorted(b for b in budgets if b)
    if len(caps) >= 2:
        lo, hi = rows[(caps[0], "packed")], rows[(caps[-1], "packed")]
        print(f"packed proportionality: budget {caps[0]} -> "
              f"{lo['mixed_ms']:.2f} ms; budget {caps[-1]} -> "
              f"{hi['mixed_ms']:.2f} ms")

    # the acceptance point: packed at budget=4 beats the dense mixed step
    d4, p4 = rows[(4, "dense")]["mixed_ms"], rows[(4, "packed")]["mixed_ms"]
    print(f"\nbudget=4 mixed step: dense {d4:.2f} ms vs packed {p4:.2f} ms "
          f"({d4 / p4:.2f}x)")
    if p4 >= d4:
        raise SystemExit(
            f"FAIL: packed mixed step ({p4:.2f} ms) not faster than dense "
            f"({d4:.2f} ms) at token_budget=4"
        )
    # the bugfix point: the fused paged read must not regress decode
    hi = caps[-1] if caps else 4
    dd = next(r["decode_step_ms"] for r in records
              if r["mode"] == "dense" and r["budget"] == hi)
    pd = next(r["decode_step_ms"] for r in records
              if r["mode"] == "paged" and r["budget"] == hi)
    print(f"budget={hi} decode step: dense {dd:.2f} ms vs paged {pd:.2f} ms "
          f"({dd / pd:.2f}x)")

    print("PASS: outputs identical across dense/packed/paged and "
          "sampled/sampled-dense (paged-int8 "
          f">= {INT8_MATCH_MIN:.0%} token match), packed step wall scales "
          "with granted tokens")
    return records


def int8_admission_record(cfg, args):
    """Page counts per KV dtype at a fixed pool-byte budget: int8 pages
    (half-width rows + f32 scales) must admit ~2x the tokens of bf16."""
    from repro.serve.kv import KVCacheSpec

    page_size = 16
    max_len = args.prompt_len + args.new_tokens
    specs = {
        dtype: KVCacheSpec(num_slots=args.batch, max_len=max_len,
                           layout="paged", page_size=page_size, kv_dtype=dtype)
        for dtype in ("bfloat16", "int8")
    }
    budget_bytes = 8 * specs["bfloat16"].bytes_per_page(cfg)  # 8 bf16 pages
    pages = {d: s.pages_for_bytes(cfg, budget_bytes) for d, s in specs.items()}
    pages_per_req = -(-max_len // page_size)
    rec = {
        "pool_bytes": budget_bytes,
        "page_size": page_size,
        "bytes_per_page": {d: s.bytes_per_page(cfg) for d, s in specs.items()},
        "pages": pages,
        "admitted_requests": {d: p // pages_per_req for d, p in pages.items()},
        "int8_over_bf16": pages["int8"] / pages["bfloat16"],
    }
    print(f"\nint8 admission at {budget_bytes / 2**20:.2f} MiB pool: "
          f"{pages['int8']} int8 pages vs {pages['bfloat16']} bf16 "
          f"({rec['int8_over_bf16']:.2f}x)")
    if rec["int8_over_bf16"] < 1.6:
        raise SystemExit(
            f"FAIL: int8 pages should admit >= 1.6x the bf16 page count at "
            f"fixed pool bytes, got {rec['int8_over_bf16']:.2f}x"
        )
    return rec


def bench_prefix_sharing(params, cfg, args):
    """Prefix-sharing record: a second request sharing a 256-token prefix
    must map the first one's pages — fewer prefill steps, fewer pool
    pages — with outputs identical to recomputing from scratch."""
    rng = np.random.default_rng(11)
    plen = max(args.prompt_len, 256)
    prefix = rng.integers(0, cfg.vocab_size, size=256).tolist()
    tails = [rng.integers(0, cfg.vocab_size, size=plen - 256).tolist()
             for _ in range(2)]
    disjoint = [rng.integers(0, cfg.vocab_size, size=plen).tolist()
                for _ in range(2)]

    def serve_two(prompts):
        eng = ContinuousBatcher(
            params, cfg, batch_slots=args.batch, max_len=plen + args.new_tokens,
            chunk_size=16, cache="paged", page_size=16,
        )
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=args.new_tokens))
            eng.run()  # sequential: request 1 arrives after request 0 finished
        return eng

    shared = serve_two([prefix + tails[0], prefix + tails[1]])
    control = serve_two(disjoint)
    rec = {
        "prompt_len": plen,
        "shared_prefix_tokens": int(sum(s.shared_tokens for s in shared.step_stats)),
        "second_request_prefill_steps": {
            "shared": shared.finished[1].ttft_steps,
            "disjoint": control.finished[1].ttft_steps,
        },
        "touched_pages": {
            "shared": shared.kv.tables.touched_pages,
            "disjoint": control.kv.tables.touched_pages,
        },
    }
    print(f"\nprefix sharing ({plen}-token prompts, 256 shared): second "
          f"request TTFT {rec['second_request_prefill_steps']['shared']} steps "
          f"vs {rec['second_request_prefill_steps']['disjoint']} disjoint; "
          f"pool pages {rec['touched_pages']['shared']} vs "
          f"{rec['touched_pages']['disjoint']}")
    if not (rec["touched_pages"]["shared"] < rec["touched_pages"]["disjoint"]):
        raise SystemExit("FAIL: shared-prefix requests did not save pool pages")
    if not (rec["second_request_prefill_steps"]["shared"]
            < rec["second_request_prefill_steps"]["disjoint"]):
        raise SystemExit("FAIL: shared-prefix request did not save prefill steps")
    return rec


def bench_speculative(params, cfg, args):
    """Speculative-decoding record: repetitive prompts (and the
    self-repeating greedy streams they induce) through the paged engine
    with and without the n-gram proposer.  Outputs must be identical; the
    spec engine must take >= 1.5x fewer engine steps per generated
    token."""
    rng = np.random.default_rng(13)
    pattern = rng.integers(0, cfg.vocab_size, size=8).tolist()
    plen = min(args.prompt_len, 64)
    new_tokens = max(args.new_tokens, 48)  # decode-heavy: spec territory
    prompts = []
    for i in range(args.batch):
        rot = pattern[i % len(pattern):] + pattern[: i % len(pattern)]
        prompts.append((rot * ((plen + 7) // 8))[:plen])

    def serve(spec):
        eng = ContinuousBatcher(
            params, cfg, batch_slots=args.batch, max_len=plen + new_tokens,
            chunk_size=16, packed=True, cache="paged", page_size=16,
            spec=SpecConfig(NGramProposer(), k=SPEC_K) if spec else None,
        )
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=new_tokens))
        eng.run()
        return eng

    base, spec = serve(False), serve(True)
    if {u: r.output for u, r in base.finished.items()} != \
            {u: r.output for u, r in spec.finished.items()}:
        raise SystemExit("FAIL: speculative outputs diverged from greedy")
    bs, ss = base.stats_summary(), spec.stats_summary()
    rec = {
        "proposer": "ngram", "k": SPEC_K,
        "prompt_len": plen, "new_tokens": new_tokens,
        "acceptance_rate": ss["acceptance_rate"],
        "steps_per_token": {"greedy": bs["steps_per_token"],
                            "spec": ss["steps_per_token"]},
        "engine_steps": {"greedy": base.steps, "spec": spec.steps},
        "step_reduction": bs["steps_per_token"] / ss["steps_per_token"],
    }
    print(f"\nspeculative (n-gram, k={SPEC_K}, repetitive prompts): "
          f"{rec['steps_per_token']['greedy']:.2f} -> "
          f"{rec['steps_per_token']['spec']:.2f} steps/token "
          f"({rec['step_reduction']:.2f}x fewer), acceptance "
          f"{rec['acceptance_rate']:.2f}")
    if rec["step_reduction"] < 1.5:
        raise SystemExit(
            f"FAIL: expected >= 1.5x fewer engine steps per token with the "
            f"n-gram proposer, got {rec['step_reduction']:.2f}x"
        )
    return rec


def bench_model_zoo(args):
    """Model-zoo rows: the generalized cache/step contract serving
    non-attention architectures through the same engine.

    ``recurrent-chunked``: the pure-SSD ``mamba2_tiny`` config through
    chunked prefill + packed decode — outputs must be token-identical to
    the single-token ``decode_step`` oracle (the carried-state chunk
    scan is exact, not approximate).

    ``moe-packed``: the ``moe_tiny`` config through the packed step with
    capacity-factor expert dispatch.  cf=inf must reproduce the dense
    every-token-through-every-expert engine *byte-identically* (the
    dense-parity record); the recorded row runs cf=1.0 and carries the
    dropped-route count (``expert_overflow`` — per-expert tau).
    """
    import math as _math

    from repro.configs import get_config

    rows = []
    new_tokens = max(args.new_tokens, 8)

    def trace(cfg, plen, seed=1):
        rng = np.random.default_rng(seed)
        return [
            Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=plen).tolist(),
                    max_new_tokens=new_tokens)
            for i in range(args.batch)
        ]

    def serve(cfg, params, plen, **kw):
        eng = ContinuousBatcher(
            params, cfg, batch_slots=args.batch, max_len=plen + new_tokens,
            chunk_size=16, **kw)
        run_once(eng, trace(cfg, plen, seed=7))  # warmup
        eng.reset_stats()
        done, _, total = run_once(eng, trace(cfg, plen))
        return eng, {u: r.output for u, r in done.items()}, total

    def row(mode, cfg, eng, outputs, total, plen=64, **extra):
        summ = eng.stats_summary()
        n_tok = sum(len(v) for v in outputs.values()) + args.batch * plen
        return {
            "mode": mode, "budget": None, "pattern": cfg.pattern,
            "tokens_per_s": n_tok / total, "total_s": total,
            "steps": eng.steps, "steps_per_token": summ["steps_per_token"],
            "mean_ttft_ms": summ["mean_ttft"] * 1e3, **extra,
        }

    # --- recurrent-chunked --------------------------------------------
    cfg = get_config("mamba2_tiny")
    params = init_params(jax.random.PRNGKey(0), cfg)
    plen = 64
    eng, outputs, total = serve(cfg, params, plen, packed=True)
    oracle = {}
    for r in trace(cfg, plen):
        cache = ContinuousBatcher(params, cfg, batch_slots=1,
                                  max_len=plen + new_tokens, chunk_size=1)
        cache.submit(Request(uid=0, prompt=list(r.prompt),
                             max_new_tokens=new_tokens))
        oracle[r.uid] = cache.run()[0].output
    if outputs != oracle:
        raise SystemExit(
            "FAIL: recurrent-chunked outputs diverged from the "
            "token-streaming oracle")
    rows.append(row("recurrent-chunked", cfg, eng, outputs, total,
                    decode_oracle_match=True))
    print(f"\nrecurrent-chunked ({cfg.name}, pattern {cfg.pattern}): "
          f"{rows[-1]['tokens_per_s']:.0f} tok/s, "
          f"{rows[-1]['steps_per_token']:.2f} steps/token, oracle match")

    # --- moe-packed ---------------------------------------------------
    cfg = get_config("moe_tiny")
    params = init_params(jax.random.PRNGKey(0), cfg)
    _, dense_out, _ = serve(cfg, params, plen)  # dense-dispatch oracle
    _, inf_out, _ = serve(cfg, params, plen, packed=True,
                          capacity_factor=_math.inf)
    if inf_out != dense_out:
        raise SystemExit(
            "FAIL: capacity dispatch at cf=inf diverged from dense MoE")
    cf = 1.0
    eng, outputs, total = serve(cfg, params, plen, packed=True,
                                capacity_factor=cf)
    ovf = eng.stats_summary()["expert_overflow_tokens"]
    rows.append(row("moe-packed", cfg, eng, outputs, total,
                    capacity_factor=cf, expert_overflow_tokens=ovf,
                    cf_inf_matches_dense=True))
    print(f"moe-packed ({cfg.name}, {cfg.n_experts} experts, cf={cf}): "
          f"{rows[-1]['tokens_per_s']:.0f} tok/s, "
          f"{ovf:.0f} dropped routes, cf=inf == dense-MoE outputs")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--chunks", type=int, nargs="+", default=[4, 16, 32])
    ap.add_argument("--budgets", type=int, nargs="+", default=None,
                    help="0 = uncapped; defaults to '0 64' for the chunk "
                         "sweep and '4 64' for --packed")
    ap.add_argument("--packed", action="store_true",
                    help="dense/packed/paged A/B: step wall must scale with "
                         "granted tokens; includes the prefix-sharing record")
    ap.add_argument("--spec", action="store_true",
                    help="with --packed: add the speculative rows (n-gram "
                         "proposer) and the repetitive-prompt steps-per-"
                         "token record (asserts >= 1.5x fewer steps/token)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable perf records (e.g. "
                         "BENCH_serve.json; the CI full lane does)")
    args = ap.parse_args()
    if args.budgets is None:
        args.budgets = [4, 64] if args.packed else [0, 64]

    cfg = ModelConfig(name="serve-bench", n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab_size=1003, sliding_window=64,
                      layer_pattern="LG", dtype="float32", remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.requests} requests x {args.prompt_len}-token prompts, "
          f"{args.batch} slots")

    def dump(payload):
        if args.json:
            meta = {
                "bench": "serve_throughput",
                "model": {"name": cfg.name, "params": cfg.param_count()},
                "workload": {
                    "requests": args.requests, "prompt_len": args.prompt_len,
                    "new_tokens": args.new_tokens, "batch_slots": args.batch,
                },
            }
            with open(args.json, "w") as f:
                json.dump({**meta, **payload}, f, indent=2)
            print(f"wrote {args.json}")

    if args.packed:
        records = bench_modes_ab(params, cfg, args)
        records += bench_model_zoo(args)
        prefix_rec = bench_prefix_sharing(params, cfg, args)
        payload = {
            "rows": records,
            "prefix_sharing": prefix_rec,
            "int8_admission": int8_admission_record(cfg, args),
        }
        if args.spec:
            payload["speculative"] = bench_speculative(params, cfg, args)
        dump(payload)
        return

    base = bench(params, cfg, args, chunk=1, budget=None)
    rows = [base]
    for chunk in args.chunks:
        for b in args.budgets:
            rows.append(bench(params, cfg, args, chunk, b or None))

    hdr = f"{'chunk':>6} {'budget':>7} {'prefill tok/s':>14} {'speedup':>8} " \
          f"{'steps':>6} {'max step tok':>13} {'mean TTFT ms':>13} {'outputs':>8}"
    print(hdr)
    print("-" * len(hdr))
    ok = True
    for r in rows:
        same = r["outputs"] == base["outputs"]
        ok &= same
        print(f"{r['chunk']:>6} {str(r['budget'] or '-'):>7} "
              f"{r['prefill_tok_s']:>14.1f} {r['prefill_tok_s']/base['prefill_tok_s']:>7.2f}x "
              f"{r['steps']:>6} {r['max_step_tokens']:>13.0f} "
              f"{r['mean_ttft_ms']:>13.1f} {'same' if same else 'DIFF':>8}")

    dump({"rows": [{k: v for k, v in r.items() if k != "outputs"} for r in rows]})
    best = max(rows[1:], key=lambda r: r["prefill_tok_s"])
    speedup = best["prefill_tok_s"] / base["prefill_tok_s"]
    print(f"\nbest chunked config: chunk={best['chunk']} budget={best['budget']} "
          f"-> {speedup:.1f}x prefill throughput vs token streaming")
    if not ok:
        raise SystemExit("FAIL: chunked outputs diverged from the streaming baseline")
    if speedup < 5.0:
        raise SystemExit(f"FAIL: expected >=5x prefill speedup, got {speedup:.2f}x")
    print("PASS: outputs identical, >=5x prefill-phase speedup")


if __name__ == "__main__":
    main()
