"""Figure 4: effective speedup vs drop rate.

Left: 32 accumulations, varying workers (16..112): the benefit grows with
scale.  Right: 112 workers, varying accumulations — diminishing returns
with more accumulations.  Post-analysis of no-drop runs, as in the paper.
"""
from __future__ import annotations

import numpy as np

from repro.core import PAPER_DELAY, simulate

from .common import write_rows


def _speedup_vs_droprate(sim, n_points=25):
    grid = np.linspace(float(sim.T_n.mean()) * 0.55, float(sim.T.max()), 200)
    out = []
    for tau in grid:
        t_iter, frac = sim.with_threshold(tau)
        out.append((1.0 - float(frac.mean()), sim.effective_speedup(tau)))
    out.sort()
    return out


def run(quick: bool = True):
    iters = 80 if quick else 300
    rows = []
    for n in (16, 32, 64, 112):
        sim = simulate(PAPER_DELAY, iters, n, 32, tc=0.5, seed=n)
        for dr, s in _speedup_vs_droprate(sim):
            rows.append({"panel": "left", "workers": n, "accumulations": 32,
                         "drop_rate": dr, "speedup": s})
    for m in (4, 12, 32, 64):
        sim = simulate(PAPER_DELAY, iters, 112, m, tc=0.5, seed=1000 + m)
        for dr, s in _speedup_vs_droprate(sim):
            rows.append({"panel": "right", "workers": 112, "accumulations": m,
                         "drop_rate": dr, "speedup": s})
    write_rows("fig4_droprate", rows)

    def best(panel, key, val):
        return max(
            (r["speedup"] for r in rows if r["panel"] == panel and r[key] == val and r["drop_rate"] < 0.12),
            default=1.0,
        )

    return [
        {"name": "fig4/best_speedup_16w", "value": round(best("left", "workers", 16), 4)},
        {"name": "fig4/best_speedup_112w", "value": round(best("left", "workers", 112), 4)},
        {"name": "fig4/best_speedup_m4", "value": round(best("right", "accumulations", 4), 4)},
        {"name": "fig4/best_speedup_m64", "value": round(best("right", "accumulations", 64), 4)},
    ]
