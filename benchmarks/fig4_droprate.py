"""Figure 4: effective speedup vs drop rate.

Left: 32 accumulations, varying workers (16..112): the benefit grows with
scale.  Right: 112 workers, varying accumulations — diminishing returns
with more accumulations.  Post-analysis of no-drop runs, as in the paper.

A third "trajectory" panel comes from a *real* training run (not
post-analysis): per-step drop rate and the tau in effect, straight off
``TrainResult.drop_rates`` / ``TrainResult.tau_series`` with the online
controller adapting to a fault scenario mid-run.
"""
from __future__ import annotations

import numpy as np

from repro.core import DropConfig, PAPER_DELAY, simulate
from repro.data import DataConfig
from repro.models import ModelConfig
from repro.train import TrainConfig, train
from repro.train.resilience import ControllerConfig, make_scenario

from .common import write_rows


def _speedup_vs_droprate(sim, n_points=25):
    grid = np.linspace(float(sim.T_n.mean()) * 0.55, float(sim.T.max()), 200)
    out = []
    for tau in grid:
        t_iter, frac = sim.with_threshold(tau)
        out.append((1.0 - float(frac.mean()), sim.effective_speedup(tau)))
    out.sort()
    return out


_TINY = ModelConfig(
    name="fig4", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
    vocab_size=131, dtype="float32", remat=False,
)
_DATA = DataConfig(vocab_size=131, seq_len=32, batch_size=64, strategy="pack", seed=0)


def _trajectory_rows(steps: int):
    """Per-step (drop_rate, tau) off a real online-tau run under faults."""
    res = train(_TINY, _DATA, TrainConfig(
        steps=steps, n_workers=8, microbatches=8, lr=1e-3, seed=0,
        drop=DropConfig(enabled=True, tau=float("inf")), online_tau=True,
        controller=ControllerConfig(warmup_steps=16, check_every=8),
        latency=make_scenario("pareto", seed=0, onset=steps // 2),
        tc=0.5, telemetry_window=32,
    ))
    taus = res.tau_series()
    return res, [
        {"panel": "trajectory", "workers": 8, "accumulations": 8,
         "drop_rate": float(d), "speedup": 1.0, "step": i,
         "tau": (None if not np.isfinite(taus[i]) else float(taus[i]))}
        for i, d in enumerate(res.drop_rates)
    ]


def run(quick: bool = True):
    iters = 80 if quick else 300
    rows = []
    for n in (16, 32, 64, 112):
        sim = simulate(PAPER_DELAY, iters, n, 32, tc=0.5, seed=n)
        for dr, s in _speedup_vs_droprate(sim):
            rows.append({"panel": "left", "workers": n, "accumulations": 32,
                         "drop_rate": dr, "speedup": s})
    for m in (4, 12, 32, 64):
        sim = simulate(PAPER_DELAY, iters, 112, m, tc=0.5, seed=1000 + m)
        for dr, s in _speedup_vs_droprate(sim):
            rows.append({"panel": "right", "workers": 112, "accumulations": m,
                         "drop_rate": dr, "speedup": s})
    write_rows("fig4_droprate", rows)

    traj_res, traj_rows = _trajectory_rows(60 if quick else 100)
    write_rows("fig4_droprate", traj_rows, fname="trajectory.csv")

    def best(panel, key, val):
        return max(
            (r["speedup"] for r in rows if r["panel"] == panel and r[key] == val and r["drop_rate"] < 0.12),
            default=1.0,
        )

    return [
        {"name": "fig4/best_speedup_16w", "value": round(best("left", "workers", 16), 4)},
        {"name": "fig4/best_speedup_112w", "value": round(best("left", "workers", 112), 4)},
        {"name": "fig4/best_speedup_m4", "value": round(best("right", "accumulations", 4), 4)},
        {"name": "fig4/best_speedup_m64", "value": round(best("right", "accumulations", 64), 4)},
        {"name": "fig4/traj_tau_changes", "value": len(traj_res.tau_trajectory) - 1},
        {"name": "fig4/traj_mean_drop", "value": round(float(np.mean(traj_res.drop_rates)), 4)},
    ]
