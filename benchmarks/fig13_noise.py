"""Figures 13/14 (appendix C.3): noise-distribution and variance studies.

E[T]/E[T_n] is the paper's indicator of DropCompute's potential: the gap
between the slowest worker and a typical worker.  Sweeps the five noise
families at matched mean/variance (fig. 13) and the lognormal variance
ladder (fig. 14), reporting the ratio and the achievable S_eff(tau*).
"""
from __future__ import annotations

from repro.core import LatencyModel, NoiseModel, simulate
from repro.core.threshold import select_threshold

from .common import write_rows

M = 12
N = 64


def _row(model, tag, iters):
    sim = simulate(model, iters, N, M, tc=0.5, seed=11)
    res = select_threshold(sim.t, sim.tc, grid_size=128)
    return {
        "setting": tag,
        "noise": model.noise.kind,
        "mean": model.noise.mean,
        "var": model.noise.var,
        "ET_over_ETn": float(sim.T.mean() / sim.T_n.mean()),
        "seff_at_tau_star": res.speedup,
        "tau_star": res.tau,
    }


def run(quick: bool = True):
    iters = 100 if quick else 400
    rows = []
    # fig 13: distribution type at mean=0.225 var=0.05 (x0.45s base => the
    # table's eps-statistics)
    for kind in ("lognormal", "normal", "bernoulli", "exponential", "gamma"):
        m = LatencyModel(base=0.45, noise=NoiseModel(kind=kind, mean=0.5, var=0.25))
        rows.append(_row(m, "fig13", iters))
    # fig 14: lognormal variance ladder
    for var in (0.25, 0.5, 0.75, 1.0, 1.25, 1.5):
        m = LatencyModel(base=0.45, noise=NoiseModel(kind="lognormal", mean=0.5, var=var))
        rows.append(_row(m, "fig14", iters))
    write_rows("fig13_noise", rows)

    ln = [r for r in rows if r["setting"] == "fig13" and r["noise"] == "lognormal"][0]
    nm = [r for r in rows if r["setting"] == "fig13" and r["noise"] == "normal"][0]
    v_lo = rows[5]
    v_hi = rows[-1]
    return [
        {"name": "fig13/ratio_lognormal", "value": round(ln["ET_over_ETn"], 3)},
        {"name": "fig13/ratio_normal", "value": round(nm["ET_over_ETn"], 3)},
        {"name": "fig14/seff_var0.25", "value": round(v_lo["seff_at_tau_star"], 3)},
        {"name": "fig14/seff_var1.5", "value": round(v_hi["seff_at_tau_star"], 3)},
    ]
