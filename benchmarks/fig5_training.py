"""Figure 5: loss-vs-steps and loss-vs-time with compute variance.

Actual training of a small LM with 64 virtual workers in the simulated
delay environment: DropCompute may need a few more steps to a target loss
but reaches it in less simulated wall-clock.
"""
from __future__ import annotations

import numpy as np

from repro.core import DropConfig, PAPER_DELAY
from repro.data import DataConfig
from repro.models import ModelConfig
from repro.train import TrainConfig, train

from .common import write_rows

MODEL = ModelConfig(
    name="fig5", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=251, dtype="float32", remat=False,
)
DATA = DataConfig(vocab_size=251, seq_len=64, batch_size=64, strategy="pack", seed=0)


def run(quick: bool = True):
    steps = 40 if quick else 150
    n_workers = 8 if quick else 64

    def go(drop):
        t = TrainConfig(
            steps=steps, n_workers=n_workers, microbatches=8, lr=1e-3,
            drop=drop, latency=PAPER_DELAY, tc=0.5,
            auto_threshold=drop.enabled, calibration_steps=10, seed=0,
        )
        return train(MODEL, DATA, t)

    base = go(DropConfig(enabled=False))
    drop = go(DropConfig(enabled=True, tau=float("inf")))

    rows = []
    for i in range(steps):
        rows.append({"method": "baseline", "step": i, "loss": base.losses[i],
                     "time": float(base.cum_time[i])})
        rows.append({"method": "dropcompute", "step": i, "loss": drop.losses[i],
                     "time": float(drop.cum_time[i])})
    write_rows("fig5_training", rows)

    # time to reach the baseline's final loss
    target = base.losses[-1]
    t_base = float(base.cum_time[-1])
    idx = next((i for i, l in enumerate(drop.losses) if l <= target), steps - 1)
    t_drop = float(drop.cum_time[idx])
    return [
        {"name": "fig5/time_saving_to_target", "value": round(1 - t_drop / t_base, 4)},
        {"name": "fig5/extra_steps_to_target", "value": int(idx - (steps - 1))},
        {"name": "fig5/mean_drop_rate", "value": round(float(np.mean(drop.drop_fractions)), 4)},
        {"name": "fig5/tau_star", "value": round(drop.tau, 4)},
    ]
