"""Figure 1: scalability of synchronous training under compute variance.

Simulated measurement up to 200 workers + analytic extrapolation (eq. 11)
to 2048, baseline vs DropCompute with the auto-selected threshold, in the
paper's simulated-delay environment (12 accumulations, lognormal noise).
"""
from __future__ import annotations

import numpy as np

from repro.core import PAPER_DELAY, optimal_tau, scale_curve, simulate
from repro.core.theory import effective_speedup, expected_max_normal
from repro.core.threshold import select_threshold

from .common import write_rows

M = 12
TC = 0.5


def run(quick: bool = True):
    workers_meas = [1, 2, 4, 8, 16, 32, 64, 128, 200]
    workers_extra = [256, 512, 1024, 2048]
    iters = 100 if quick else 400
    rows = []

    # threshold from a profiling run at 64 workers (Algorithm 2)
    prof = simulate(PAPER_DELAY, 50, 64, M, tc=TC, seed=7)
    tau = select_threshold(prof.t, prof.tc).tau

    base = scale_curve(PAPER_DELAY, workers_meas, M, TC, iters=iters)
    drop = scale_curve(PAPER_DELAY, workers_meas, M, TC, iters=iters, tau=tau)
    for n in workers_meas:
        rows.append({
            "workers": n, "source": "simulated",
            "throughput_baseline": base[n][0], "efficiency_baseline": base[n][1],
            "throughput_dropcompute": drop[n][0], "efficiency_dropcompute": drop[n][1],
            "speedup": drop[n][0] / base[n][0],
        })

    # analytic extrapolation (eq. 11 with the paper-lognormal mu/sigma)
    mu, sig = PAPER_DELAY.mean, PAPER_DELAY.std
    for n in workers_meas + workers_extra:
        e_t = expected_max_normal(M * mu, np.sqrt(M) * sig, n)
        s = effective_speedup(tau, mu, sig, M, n, TC)
        thr_base = n * M / (e_t + TC)
        rows.append({
            "workers": n, "source": "analytic",
            "throughput_baseline": thr_base, "efficiency_baseline": thr_base / (n * M / (M * mu + TC)),
            "throughput_dropcompute": thr_base * s, "efficiency_dropcompute": s * thr_base / (n * M / (M * mu + TC)),
            "speedup": s,
        })

    write_rows("fig1_scale", rows)

    meas200 = [r for r in rows if r["source"] == "simulated" and r["workers"] == 200][0]
    ana2048 = [r for r in rows if r["source"] == "analytic" and r["workers"] == 2048][0]
    return [
        {"name": "fig1/speedup@200workers", "value": round(meas200["speedup"], 4)},
        {"name": "fig1/speedup@2048workers_analytic", "value": round(ana2048["speedup"], 4)},
        {"name": "fig1/efficiency_baseline@200", "value": round(meas200["efficiency_baseline"], 4)},
    ]
