"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = module wall time in
microseconds / number of derived metrics; derived = the metric value).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig1,...]
"""
import argparse
import sys
import time
import traceback

MODULES = [
    "fig1_scale",
    "fig2_variance",
    "fig3_seff",
    "fig4_droprate",
    "fig5_training",
    "train_tail",
    "table1_generalization",
    "fig12_localsgd",
    "fig13_noise",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="long (paper-scale) settings")
    ap.add_argument("--only", default="", help="comma-separated module filter")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    print("name,us_per_call,derived")
    failed = []
    for name in MODULES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            derived = mod.run(quick=not args.full)
        except Exception as e:  # keep the harness going, report at the end
            failed.append((name, repr(e)))
            traceback.print_exc(limit=3, file=sys.stderr)
            continue
        us = (time.perf_counter() - t0) * 1e6
        per = us / max(len(derived), 1)
        for d in derived:
            print(f"{d['name']},{per:.0f},{d['value']}")
        sys.stdout.flush()

    if failed:
        print("FAILED:", failed, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
