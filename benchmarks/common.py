"""Shared helpers for the benchmark harness.

Besides the results-directory plumbing, this module owns the **seeded
serving workload generators** every serving benchmark and example draws
requests from (``make_requests`` / ``mixed_requests``).  One generator,
one seed convention: the same ``(n, prompt_len, new_tokens, vocab,
seed)`` always produces token-identical request sets, so A/B comparisons
across engines — and across PRs — replay the exact same workload.
"""
from __future__ import annotations

import csv
import json
import os
import time
from pathlib import Path
from typing import Dict, Iterable, List

import numpy as np

RESULTS = Path(__file__).resolve().parent / "results"


def out_dir(name: str) -> Path:
    p = RESULTS / name
    p.mkdir(parents=True, exist_ok=True)
    return p


def write_rows(name: str, rows: List[Dict], fname: str = "data.csv") -> Path:
    p = out_dir(name) / fname
    if rows:
        with open(p, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return p


def write_json(name: str, obj, fname: str = "data.json") -> Path:
    p = out_dir(name) / fname
    p.write_text(json.dumps(obj, indent=1, default=float))
    return p


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


# ---------------------------------------------------------------------------
# Seeded serving workloads (shared by benchmarks/, examples/, tests/)
# ---------------------------------------------------------------------------


def seeded_prompts(n: int, prompt_len: int, vocab: int, seed: int = 1,
                   shared_prefix: int = 0) -> List[List[int]]:
    """``n`` uniform-random token prompts, optionally all starting with
    the same ``shared_prefix``-token prefix (drawn once, from the same
    stream — the prefix-cache workloads).  Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    n_prefix = min(shared_prefix, max(prompt_len - 1, 0))
    prefix = rng.integers(0, vocab, size=n_prefix).tolist()
    return [
        prefix + rng.integers(0, vocab, size=prompt_len - n_prefix).tolist()
        for _ in range(n)
    ]


def _req_sampling(sampling, uid: int):
    """Per-request sampling params: base params re-seeded per uid so every
    request draws an independent, reproducible stream.  Duck-typed on
    ``with_seed`` to keep this module jax-free (``sampling`` is a
    ``repro.serve.SamplingParams`` when given)."""
    if sampling is None:
        return {}
    return {"sampling": sampling.with_seed(sampling.seed + uid)}


def make_requests(n: int, prompt_len: int, new_tokens: int, vocab: int,
                  seed: int = 1, shared_prefix: int = 0,
                  sampling=None) -> List:
    """Uniform-length request set (uids ``0..n-1``); the serving
    benchmarks' default workload.  ``sampling`` (a ``SamplingParams``)
    turns on stochastic decoding: request ``i`` gets
    ``sampling.with_seed(sampling.seed + i)``."""
    from repro.serve import Request  # lazy: keep common.py jax-free

    return [
        Request(uid=i, prompt=p, max_new_tokens=new_tokens,
                **_req_sampling(sampling, i))
        for i, p in enumerate(seeded_prompts(n, prompt_len, vocab, seed,
                                             shared_prefix))
    ]


def mixed_requests(n: int, prompt_len: int, new_tokens: int, vocab: int,
                   seed: int = 1, sampling=None) -> List:
    """Alternating long/short prompts -> engine steps that carry decode
    AND prefill work (the shapes where token packing differs from the
    dense program).  ``sampling`` seeds per-request streams exactly as in
    :func:`make_requests`."""
    from repro.serve import Request  # lazy: keep common.py jax-free

    rng = np.random.default_rng(seed)
    lens = [prompt_len if i % 2 else max(prompt_len // 4, 8)
            for i in range(n)]
    return [
        Request(uid=i, prompt=rng.integers(0, vocab, size=m).tolist(),
                max_new_tokens=new_tokens, **_req_sampling(sampling, i))
        for i, m in enumerate(lens)
    ]
