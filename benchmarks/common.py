"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import csv
import json
import os
import time
from pathlib import Path
from typing import Dict, Iterable, List

RESULTS = Path(__file__).resolve().parent / "results"


def out_dir(name: str) -> Path:
    p = RESULTS / name
    p.mkdir(parents=True, exist_ok=True)
    return p


def write_rows(name: str, rows: List[Dict], fname: str = "data.csv") -> Path:
    p = out_dir(name) / fname
    if rows:
        with open(p, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return p


def write_json(name: str, obj, fname: str = "data.json") -> Path:
    p = out_dir(name) / fname
    p.write_text(json.dumps(obj, indent=1, default=float))
    return p


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
