"""Tail-tolerant training: fault scenarios x tau policies.

Trains the same tiny LM under seeded ``repro.train.resilience`` fault
scenarios with three threshold policies:

* ``off``     — no DropCompute (tau = inf);
* ``static``  — the original one-shot Algorithm-2 calibration (tau picked
  once after ``CALIBRATION`` steps, never revisited);
* ``online``  — the ``TauController`` re-estimating tau* from rolling
  telemetry, with hysteresis / drop guardrails / recompile amortization.

Every policy under one scenario replays the *identical* per-step latency
stream (``sample_at`` keyed by ``(seed, step)``), so the sweep is a true
A/B/C.  The headline record is the ``pareto`` scenario — a heavy Pareto
tail plus a mid-run 2.5x base ramp at step ``ONSET`` — where the
statically calibrated tau goes stale and the online controller re-adapts:
acceptance requires online goodput strictly above both off and static,
with the measured effective speedup inside the ``core.theory`` eq. (11)
prediction band.  The ``none`` scenario pins the parity contract: with no
tail the controller is a structural no-op and the online run's losses are
bit-identical to the no-drop baseline.

``--json`` writes the committed ``BENCH_train.json`` at the repo root
(schema-gated by ``tests/test_bench_train_record.py``; the full CI lane
regenerates it and fails on missing scenarios/policies).

    PYTHONPATH=src python -m benchmarks.train_tail --json BENCH_train.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.core import DropConfig, theory
from repro.data import DataConfig
from repro.models import ModelConfig
from repro.train import TrainConfig, train
from repro.train.resilience import ControllerConfig, make_scenario

from .common import write_rows

MODEL = ModelConfig(
    name="tiny", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
    vocab_size=131, dtype="float32", remat=False,
)
DATA = DataConfig(vocab_size=131, seq_len=32, batch_size=64, strategy="pack", seed=0)

N, M, TC = 8, 8, 0.5
STEPS = 100
CALIBRATION = 20  # static policy's one-shot profiling window
ONSET = 40  # step where the pareto ramp / bad node kicks in
SEED = 0
POLICIES = ("off", "static", "online")
SCENARIOS_QUICK = ("none", "pareto")
SCENARIOS_FULL = ("none", "pareto", "lognormal", "badnode", "stall")
THEORY_BAND = 0.30  # |measured/predicted - 1| tolerance (fig. 3 scale errors)


def _train_one(scenario: str, policy: str, steps: int):
    latency = make_scenario(scenario, seed=SEED, onset=ONSET)
    kw: Dict = dict(
        steps=steps, n_workers=N, microbatches=M, lr=1e-3, seed=SEED,
        latency=latency, tc=TC, calibration_steps=CALIBRATION,
        telemetry_window=32, log_every=0,
    )
    if policy == "off":
        kw["drop"] = DropConfig(enabled=False)
    elif policy == "static":
        kw["drop"] = DropConfig(enabled=True, tau=float("inf"))
        kw["auto_threshold"] = True
    elif policy == "online":
        kw["drop"] = DropConfig(enabled=True, tau=float("inf"))
        kw["online_tau"] = True
        kw["controller"] = ControllerConfig(warmup_steps=16, check_every=8)
    else:
        raise ValueError(policy)
    return train(MODEL, DATA, TrainConfig(**kw))


def _goodput(res, lo: int = 0) -> float:
    """Completed micro-batches per simulated second over steps [lo:)."""
    good = N * M * float(np.sum(1.0 - np.asarray(res.drop_fractions[lo:])))
    return good / float(np.sum(res.sim_times[lo:]))


def _row(scenario: str, policy: str, res) -> Dict:
    return {
        "scenario": scenario,
        "policy": policy,
        "throughput_mb_s": round(_goodput(res), 4),
        "drop_rate": round(float(np.mean(res.drop_fractions)), 4),
        "final_loss": round(res.metrics["final_loss"], 4),
        "mean_iter_s": round(float(np.mean(res.sim_times)), 4),
        "tau_final": (None if not np.isfinite(res.tau) else round(res.tau, 4)),
        "tau_changes": res.metrics["tau_changes"],
        "tau_trajectory": [
            [int(s), (None if not np.isfinite(t) else round(float(t), 4))]
            for s, t in res.tau_trajectory
        ],
    }


def _theory_check(results: Dict[str, Dict], steps: int) -> Dict:
    """Measured vs predicted effective speedup on the acceptance scenario.

    Measured: online/off goodput ratio over the stationary tail segment
    (from the online run's last tau change on — one tau, one latency
    regime).  Predicted: eq. (11) at that tau with the segment's empirical
    micro-batch moments and E[T] plugged in (the fig. 3b honesty clause:
    the Gaussian max is poor on Pareto tails, the E[T] plug-in is not).
    """
    online, off = results["online"], results["off"]
    lo = int(online.tau_trajectory[-1][0])
    tau = float(online.tau_trajectory[-1][1])
    measured = _goodput(online, lo) / _goodput(off, lo)

    lat = make_scenario("pareto", seed=SEED, onset=ONSET)
    seg = np.stack([lat.sample_at(s, N, M, seed=SEED + 1) for s in range(lo, steps)])
    mu, sigma = float(seg.mean()), float(seg.std())
    e_t = float(seg.sum(axis=-1).max(axis=-1).mean())
    predicted = theory.effective_speedup(tau, mu, sigma, M, N, tc=TC, e_t=e_t)
    ratio = measured / predicted
    return {
        "segment_start": lo,
        "tau": round(tau, 4),
        "measured_speedup": round(measured, 4),
        "predicted_speedup": round(float(predicted), 4),
        "ratio": round(ratio, 4),
        "band": THEORY_BAND,
        "within_band": bool(abs(ratio - 1.0) <= THEORY_BAND),
    }


def sweep(steps: int = STEPS, scenarios=SCENARIOS_FULL) -> Dict:
    rows: List[Dict] = []
    keep: Dict[str, Dict[str, object]] = {}
    for scenario in scenarios:
        keep[scenario] = {}
        for policy in POLICIES:
            res = _train_one(scenario, policy, steps)
            keep[scenario][policy] = res
            rows.append(_row(scenario, policy, res))

    pareto = keep.get("pareto", {})
    acceptance = {}
    if pareto:
        g = {p: _goodput(pareto[p]) for p in POLICIES}
        acceptance = {
            "scenario": "pareto",
            "online_vs_off": round(g["online"] / g["off"], 4),
            "online_vs_static": round(g["online"] / g["static"], 4),
            "strictly_better": bool(
                g["online"] > g["off"] and g["online"] > g["static"]
            ),
            "theory": _theory_check(pareto, steps),
        }

    parity = {}
    if "none" in keep:
        off, online = keep["none"]["off"], keep["none"]["online"]
        parity = {
            "scenario": "none",
            "losses_identical": bool(
                np.array_equal(np.asarray(off.losses), np.asarray(online.losses))
            ),
            "online_tau_changes": online.metrics["tau_changes"],
            "online_mean_drop": round(float(np.mean(online.drop_fractions)), 6),
        }

    return {
        "config": {
            "model": MODEL.name, "n_workers": N, "microbatches": M,
            "steps": steps, "tc": TC, "onset": ONSET,
            "calibration_steps": CALIBRATION, "seed": SEED,
            "scenarios": list(scenarios), "policies": list(POLICIES),
        },
        "rows": rows,
        "acceptance": acceptance,
        "parity": parity,
    }


def run(quick: bool = True):
    """benchmarks.run entry: derived metrics for the CSV harness."""
    record = sweep(
        steps=60 if quick else STEPS,
        scenarios=SCENARIOS_QUICK if quick else SCENARIOS_FULL,
    )
    write_rows("train_tail", record["rows"])
    acc, par = record["acceptance"], record["parity"]
    return [
        {"name": "train_tail/online_vs_off", "value": acc["online_vs_off"]},
        {"name": "train_tail/online_vs_static", "value": acc["online_vs_static"]},
        {"name": "train_tail/theory_ratio", "value": acc["theory"]["ratio"]},
        {"name": "train_tail/parity_identical", "value": int(par["losses_identical"])},
    ]


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default="", help="write the record here (e.g. BENCH_train.json)")
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--quick", action="store_true", help="2 scenarios, fewer steps")
    args = ap.parse_args(argv)

    record = sweep(
        steps=min(args.steps, 60) if args.quick else args.steps,
        scenarios=SCENARIOS_QUICK if args.quick else SCENARIOS_FULL,
    )
    write_rows("train_tail", record["rows"])
    print(json.dumps({k: record[k] for k in ("acceptance", "parity")}, indent=1))
    if args.json:
        path = os.path.abspath(args.json)
        with open(path, "w") as f:
            json.dump(record, f, indent=1, default=float)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
