"""Heavy-tail traffic replay through the async serving front-end.

    PYTHONPATH=src python benchmarks/traffic_replay.py --requests 1000 \
        --json BENCH_serve.json

An **open-loop** workload generator — arrivals happen on their own clock,
regardless of whether the engine keeps up, which is what real traffic
does and what closed-loop (submit-on-completion) benchmarks structurally
cannot show — replayed through :class:`repro.serve.AsyncEngine`:

* **Poisson arrivals** at ``--rps`` (exponential inter-arrival times);
* **Zipf-shared prompt prefixes**: each request draws one of
  ``--prefix-groups`` prompt prefixes with Zipf(``--zipf-a``) popularity,
  so hot prefixes recur and exercise the paged prefix cache exactly the
  way templated production prompts do;
* **log-normal long-tail lengths** for both prompt and output — the
  per-request compute variance that makes tail latency, not mean
  throughput, the binding constraint (the serving mirror of the paper's
  per-step compute-variance argument);
* a per-request **TTFT deadline SLO** (``--deadline``): requests whose
  first token misses it are dropped by the front-end — slot and pages
  reclaimed — and count against goodput, not throughput;
* **stochastic decoding** by default (``--temperature 0.8 --top-p
  0.95``): every request carries a workload-seeded PRNG seed, so the
  replay exercises the sampling path end-to-end while staying fully
  reproducible (``--temperature 0`` restores greedy).

The replay records p50/p99 TTFT (split into queue wait and post-
admission prefill latency), time-per-output-token, and **deadline
goodput** (requests and tokens served within SLO per wall second) as the
``traffic`` record of ``BENCH_serve.json`` (``--json`` merges into an
existing record file; the CI full lane regenerates it).  After the
replay drains it asserts the paged pool leaked zero pages.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import math
import os
import sys
import time
from typing import Dict, List, Optional

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import seeded_prompts  # noqa: E402


@dataclasses.dataclass(frozen=True)
class TrafficRequest:
    """One generated arrival (everything seeded, nothing wall-clock)."""

    uid: int
    arrival_s: float  # offset from replay start
    prompt: tuple
    max_new_tokens: int
    deadline_s: Optional[float]
    group: int  # prefix-group id (-1 = no shared prefix)
    seed: int = 0  # per-request sampling seed (drawn from the workload rng)


def _lognormal_lengths(rng, n, median, sigma, lo, hi):
    return np.clip(
        np.rint(rng.lognormal(math.log(median), sigma, size=n)), lo, hi
    ).astype(int)


def build_workload(
    n_requests: int,
    vocab: int,
    seed: int,
    *,
    rps: float = 75.0,
    zipf_a: float = 1.1,
    prefix_groups: int = 24,
    prefix_len: int = 64,
    prompt_median: int = 48,
    prompt_sigma: float = 0.6,
    max_prompt: int = 192,
    out_median: int = 8,
    out_sigma: float = 0.6,
    max_new: int = 32,
    deadline_s: Optional[float] = 5.0,
) -> List[TrafficRequest]:
    """Seeded heavy-tail workload: same arguments -> token-identical
    request set with identical arrival times (the determinism contract
    ``tests/test_traffic_replay.py`` pins).

    A request joins a Zipf-popular prefix group only when its sampled
    prompt is strictly longer than the group prefix (the tail keeps every
    prompt unique); shorter prompts stay disjoint (``group == -1``).
    """
    if prefix_len >= max_prompt:
        raise ValueError("prefix_len must leave room for a unique tail")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rps, size=n_requests))
    ranks = np.arange(1, prefix_groups + 1, dtype=float)
    popularity = ranks ** -zipf_a
    popularity /= popularity.sum()
    groups = rng.choice(prefix_groups, size=n_requests, p=popularity)
    prefixes = seeded_prompts(prefix_groups, prefix_len, vocab, seed=seed + 1)
    prompt_lens = _lognormal_lengths(
        rng, n_requests, prompt_median, prompt_sigma, 1, max_prompt
    )
    out_lens = _lognormal_lengths(
        rng, n_requests, out_median, out_sigma, 1, max_new
    )
    samp_seeds = rng.integers(0, 2**31, size=n_requests)
    out = []
    for i in range(n_requests):
        plen, g = int(prompt_lens[i]), int(groups[i])
        if plen > prefix_len:
            tail = rng.integers(0, vocab, size=plen - prefix_len).tolist()
            prompt = tuple(prefixes[g]) + tuple(tail)
        else:
            g = -1
            prompt = tuple(rng.integers(0, vocab, size=plen).tolist())
        out.append(
            TrafficRequest(
                uid=i,
                arrival_s=float(arrivals[i]),
                prompt=prompt,
                max_new_tokens=int(out_lens[i]),
                deadline_s=deadline_s,
                group=g,
                seed=int(samp_seeds[i]),
            )
        )
    return out


def _dist_ms(values: List[float]) -> Dict[str, float]:
    if not values:
        return {"mean": float("nan"), "p50": float("nan"), "p99": float("nan")}
    arr = np.asarray(values) * 1e3
    return {
        "mean": float(arr.mean()),
        "p50": float(np.quantile(arr, 0.50)),
        "p99": float(np.quantile(arr, 0.99)),
    }


async def replay(frontend, workload: List[TrafficRequest],
                 *, time_scale: float = 1.0, sampling=None) -> Dict:
    """Open-loop replay: each request fires at its arrival time (scaled
    by ``time_scale``) no matter how far behind the engine is.
    ``sampling`` (a ``SamplingParams``) turns on stochastic decoding:
    request ``i`` streams from ``sampling.with_seed(workload[i].seed)``,
    so the realization is pinned by the workload seed.  Returns the raw
    per-request outcomes; aggregation lives in :func:`summarize`."""
    from repro.serve import AdmissionError

    t0 = time.perf_counter()
    results = [None] * len(workload)

    async def one(item: TrafficRequest):
        delay = item.arrival_s * time_scale - (time.perf_counter() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        try:
            stream = await frontend.submit(
                list(item.prompt), item.max_new_tokens,
                uid=item.uid, deadline_s=item.deadline_s,
                sampling=(sampling.with_seed(item.seed)
                          if sampling is not None else None),
            )
        except AdmissionError:
            results[item.uid] = {"status": "rejected", "tokens": 0,
                                 "met": False, "group": item.group}
            return
        await stream.collect()
        r = stream.request
        tpot = None
        if len(stream.tokens) > 1 and r.first_token_at is not None:
            tpot = (r.finished_at - r.first_token_at) / (len(stream.tokens) - 1)
        results[item.uid] = {
            "status": stream.status,
            "tokens": len(stream.tokens),
            "met": stream.met_deadline and stream.status == "finished",
            "ttft": stream.ttft,
            "queue_wait": stream.queue_wait,
            "admitted_ttft": r.admitted_ttft,
            "tpot": tpot,
            "group": item.group,
        }

    await asyncio.gather(*(one(item) for item in workload))
    wall = time.perf_counter() - t0
    return {"results": results, "wall_s": wall}


def summarize(raw: Dict, workload: List[TrafficRequest], engine,
              args) -> Dict:
    results, wall = raw["results"], raw["wall_s"]
    by_status: Dict[str, int] = {}
    for r in results:
        by_status[r["status"]] = by_status.get(r["status"], 0) + 1
    met = [r for r in results if r["met"]]
    finished = [r for r in results if r["status"] == "finished"]
    summ = engine.stats_summary()
    leaked = engine.kv.tables.used_pages if engine.kv is not None else 0
    prompt_lens = [len(w.prompt) for w in workload]
    out_lens = [w.max_new_tokens for w in workload]
    return {
        "requests": len(workload),
        "seed": args.seed,
        "arrival": {
            "process": "poisson",
            "rps": args.rps,
            "span_s": float(workload[-1].arrival_s),
        },
        "prefix": {
            "groups": args.prefix_groups,
            "len": args.prefix_len,
            "zipf_a": args.zipf_a,
            "grouped_requests": sum(1 for w in workload if w.group >= 0),
        },
        "lengths": {
            "prompt_p50": float(np.quantile(prompt_lens, 0.5)),
            "prompt_p99": float(np.quantile(prompt_lens, 0.99)),
            "output_p50": float(np.quantile(out_lens, 0.5)),
            "output_p99": float(np.quantile(out_lens, 0.99)),
        },
        "deadline_s": args.deadline,
        "sampling": (
            {"temperature": args.temperature, "top_k": args.top_k,
             "top_p": args.top_p, "per_request_seeds": True}
            if args.temperature > 0 else {"temperature": 0.0}
        ),
        "outcomes": {
            "finished": by_status.get("finished", 0),
            "dropped": by_status.get("dropped", 0),
            "rejected": by_status.get("rejected", 0),
            "cancelled": by_status.get("cancelled", 0),
        },
        "ttft_ms": _dist_ms([r["ttft"] for r in finished
                             if r.get("ttft") is not None]),
        "queue_wait_ms": _dist_ms([r["queue_wait"] for r in finished
                                   if r.get("queue_wait") is not None]),
        "admitted_ttft_ms": _dist_ms([r["admitted_ttft"] for r in finished
                                      if r.get("admitted_ttft") is not None]),
        "tpot_ms": _dist_ms([r["tpot"] for r in finished
                             if r.get("tpot") is not None]),
        "goodput": {
            "met_requests": len(met),
            "met_fraction": len(met) / len(workload),
            "met_tokens_per_s": sum(r["tokens"] for r in met) / wall,
            "tokens_per_s": sum(r["tokens"] for r in results) / wall,
        },
        "wall_s": wall,
        "engine": {
            "mode": "packed+paged",
            "steps": engine.steps,
            "batch_slots": args.batch,
            "token_budget": args.token_budget,
            "max_queue": args.max_queue,
            "shared_prompt_tokens": summ.get("shared_tokens", 0.0),
            "peak_used_pages": summ.get("peak_used_pages", 0.0),
            "mean_queued_requests": summ["mean_queued_requests"],
        },
        "leaked_pages": int(leaked),
    }


def merge_json(path: str, record: Dict) -> None:
    """Merge the ``traffic`` record into an existing benchmark file (the
    serve-throughput rows live there too) rather than clobbering it."""
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["traffic"] = record
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"merged traffic record into {path}")


def build_engine(args):
    import jax

    from repro.models import ModelConfig
    from repro.models.model import init_params
    from repro.serve import ContinuousBatcher

    cfg = ModelConfig(name="traffic-bench", n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab_size=1003,
                      sliding_window=64, layer_pattern="LG", dtype="float32",
                      remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatcher(
        params, cfg, batch_slots=args.batch,
        max_len=args.max_prompt + args.max_new,
        chunk_size=args.chunk, token_budget=args.token_budget,
        max_queue=args.max_queue, packed=True,
        cache="paged", page_size=args.page_size,
    )
    return eng, cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rps", type=float, default=75.0,
                    help="Poisson arrival rate (requests/second)")
    ap.add_argument("--zipf-a", type=float, default=1.1)
    ap.add_argument("--prefix-groups", type=int, default=24)
    ap.add_argument("--prefix-len", type=int, default=64)
    ap.add_argument("--prompt-median", type=int, default=48)
    ap.add_argument("--prompt-sigma", type=float, default=0.6)
    ap.add_argument("--max-prompt", type=int, default=192)
    ap.add_argument("--out-median", type=int, default=8)
    ap.add_argument("--out-sigma", type=float, default=0.6)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--deadline", type=float, default=5.0,
                    help="per-request TTFT SLO in seconds (0 = none)")
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="sampling temperature (0 = greedy decoding)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation (0 = off)")
    ap.add_argument("--top-p", type=float, default=0.95,
                    help="nucleus truncation (1.0 = off)")
    ap.add_argument("--batch", type=int, default=16, help="cache slots")
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--token-budget", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-queue", type=int, default=32,
                    help="engine admission queue bound (overflow parks in "
                         "the front-end waiting room)")
    ap.add_argument("--waiting-room", type=int, default=4096)
    ap.add_argument("--queue-timeout", type=float, default=0.0,
                    help="waiting-room admission timeout in seconds "
                         "(0 = none)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="stretch (>1) or compress (<1) arrival times")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge the traffic record into this benchmark "
                         "file (e.g. BENCH_serve.json)")
    args = ap.parse_args(argv)

    eng, cfg = build_engine(args)
    workload = build_workload(
        args.requests, cfg.vocab_size, args.seed, rps=args.rps,
        zipf_a=args.zipf_a, prefix_groups=args.prefix_groups,
        prefix_len=args.prefix_len, prompt_median=args.prompt_median,
        prompt_sigma=args.prompt_sigma, max_prompt=args.max_prompt,
        out_median=args.out_median, out_sigma=args.out_sigma,
        max_new=args.max_new, deadline_s=args.deadline or None,
    )
    n_tok = sum(len(w.prompt) + w.max_new_tokens for w in workload)
    samp = ("greedy" if args.temperature <= 0 else
            f"T={args.temperature} top_p={args.top_p}"
            + (f" top_k={args.top_k}" if args.top_k else ""))
    print(f"replaying {len(workload)} requests ({n_tok} worst-case tokens) "
          f"at {args.rps} req/s over {workload[-1].arrival_s:.1f}s, "
          f"deadline {args.deadline}s, {args.batch} slots, {samp}")

    from repro.serve import AsyncEngine, SamplingParams

    base_sampling = (
        SamplingParams(temperature=args.temperature, top_k=args.top_k,
                       top_p=args.top_p)
        if args.temperature > 0 else None
    )

    async def go():
        fe = AsyncEngine(eng, waiting_room=args.waiting_room,
                         queue_timeout=args.queue_timeout or None)
        await fe.start()
        try:
            # warm the two packed step programs off the clock: XLA compile
            # would otherwise land on the first unlucky requests' TTFT
            warm = await fe.submit([1] * (args.chunk + 1), 2)
            await warm.collect()
            while fe.in_flight:
                await asyncio.sleep(0.002)
            eng.reset_stats()
            return await replay(fe, workload, time_scale=args.time_scale,
                                sampling=base_sampling)
        finally:
            await fe.stop(drain=True)

    raw = asyncio.run(go())
    rec = summarize(raw, workload, eng, args)

    o, g, t = rec["outcomes"], rec["goodput"], rec["ttft_ms"]
    print(f"finished {o['finished']}  dropped {o['dropped']}  "
          f"rejected {o['rejected']}  in {rec['wall_s']:.1f}s")
    print(f"TTFT ms: p50 {t['p50']:.0f}  p99 {t['p99']:.0f}  "
          f"(queue-wait p99 {rec['queue_wait_ms']['p99']:.0f}, "
          f"admitted p99 {rec['admitted_ttft_ms']['p99']:.0f})")
    print(f"TPOT ms: p50 {rec['tpot_ms']['p50']:.1f}  "
          f"p99 {rec['tpot_ms']['p99']:.1f}")
    print(f"goodput: {g['met_fraction']:.1%} of requests within SLO, "
          f"{g['met_tokens_per_s']:.0f} tok/s within-deadline "
          f"({g['tokens_per_s']:.0f} tok/s served overall)")
    print(f"prefix cache: {rec['engine']['shared_prompt_tokens']:.0f} prompt "
          f"tokens served from shared pages; "
          f"peak {rec['engine']['peak_used_pages']:.0f} pages")

    if rec["leaked_pages"]:
        raise SystemExit(
            f"FAIL: {rec['leaked_pages']} pages still referenced after drain"
        )
    eng.kv.check_invariants()
    total = sum(rec["outcomes"].values())
    if total != len(workload):
        raise SystemExit(
            f"FAIL: outcome conservation: {rec['outcomes']} != {len(workload)}"
        )
    if args.json:
        merge_json(args.json, rec)
    print("PASS: replay drained, zero leaked pages, invariants clean")
    return rec


if __name__ == "__main__":
    main()
