"""Table 1: generalization under drop rates + compensation methods.

(a) drop rates 0 / ~3 / ~6 / ~10%: final eval loss vs no-drop baseline;
(b) at ~10% drops: compensation by extra steps, by increased batch, and by
    recomputation (resampling dropped data), vs none.

Uses the small-LM proxy (eval loss on held-out synthetic data stands in
for SQuAD F1 — the mechanism under test, stochastic batch size, is
identical).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DropConfig, LatencyModel, NoiseModel
from repro.data import DataConfig, batch_at
from repro.models import ModelConfig
from repro.models.model import loss_fn
from repro.train import TrainConfig, train

from .common import write_rows

MODEL = ModelConfig(
    name="t1", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=251, dtype="float32", remat=False,
)
DATA = DataConfig(vocab_size=251, seq_len=64, batch_size=32, strategy="pack", seed=0)
DELAY = LatencyModel(base=0.45, noise=NoiseModel(kind="paper_lognormal"))
# thresholds tuned to hit ~3/6/10% drop rates in this environment
TAUS = {0.0: float("inf"), 0.03: 3.05, 0.06: 2.85, 0.10: 2.7}


def eval_loss(params):
    cfg = dataclasses.replace(DATA, seed=999)
    tot, w = 0.0, 0.0
    for s in range(4):
        b = batch_at(s, cfg)
        ls, ws = loss_fn(params, MODEL, {k: jnp.asarray(v) for k, v in b.items() if k != "lengths"})
        tot += float(ls)
        w += float(ws)
    return tot / w


def go(tau, steps, batch_mult=1.0, seed=0):
    data = dataclasses.replace(DATA, batch_size=int(DATA.batch_size * batch_mult))
    t = TrainConfig(
        steps=steps, n_workers=4, microbatches=8, lr=1e-3,
        drop=DropConfig(enabled=np.isfinite(tau), tau=tau),
        latency=DELAY, tc=0.5, seed=seed,
    )
    return train(MODEL, data, t, eval_fn=eval_loss)


def run(quick: bool = True):
    steps = 40 if quick else 200
    rows, derived = [], []

    # (a) drop-rate sweep
    base_eval = None
    for target, tau in TAUS.items():
        r = go(tau, steps)
        rows.append({"table": "a", "target_drop": target, "actual_drop": r.metrics["mean_drop"],
                     "eval_loss": r.metrics["eval"], "method": "none"})
        if target == 0.0:
            base_eval = r.metrics["eval"]
        derived.append({
            "name": f"table1a/eval_delta_drop{int(target*100)}pct",
            "value": round(r.metrics["eval"] - base_eval, 4),
        })

    # (b) compensation at ~10%
    r10 = [r for r in rows if r["target_drop"] == 0.10][0]
    extra = go(TAUS[0.10], int(steps * 1.11))
    rows.append({"table": "b", "target_drop": 0.10, "actual_drop": extra.metrics["mean_drop"],
                 "eval_loss": extra.metrics["eval"], "method": "extra_steps_11pct"})
    # batch multiple must keep divisibility by workers*microbatches (32)
    bigger = go(TAUS[0.10], steps, batch_mult=2.0)
    rows.append({"table": "b", "target_drop": 0.10, "actual_drop": bigger.metrics["mean_drop"],
                 "eval_loss": bigger.metrics["eval"], "method": "increased_batch"})
    # recomputation: different data order re-exposes dropped samples
    recomp = go(TAUS[0.10], steps, seed=1)
    rows.append({"table": "b", "target_drop": 0.10, "actual_drop": recomp.metrics["mean_drop"],
                 "eval_loss": recomp.metrics["eval"], "method": "recompute_resample"})

    write_rows("table1_generalization", rows)
    derived += [
        {"name": "table1b/extra_steps_eval", "value": round(extra.metrics["eval"], 4)},
        {"name": "table1b/increased_batch_eval", "value": round(bigger.metrics["eval"], 4)},
        {"name": "table1b/recompute_eval", "value": round(recomp.metrics["eval"], 4)},
    ]
    return derived
