"""Figure 2: iteration-time distribution with and without DropCompute.

Left panel: per-worker step times T_n (no drops).  Right panel: the
max-over-workers iteration time T under different drop rates (thresholds
chosen by target completion).  Reports distribution summaries.
"""
from __future__ import annotations

import numpy as np

from repro.core import PAPER_DELAY, simulate

from .common import write_json, write_rows

M = 12
WORKERS = 200


def run(quick: bool = True):
    iters = 150 if quick else 500
    sim = simulate(PAPER_DELAY, iters, WORKERS, M, tc=0.5, seed=0)

    rows = [{
        "setting": "worker_step_time",
        "mean": float(sim.T_n.mean()), "std": float(sim.T_n.std()),
        "p50": float(np.median(sim.T_n)), "p99": float(np.quantile(sim.T_n, 0.99)),
    }, {
        "setting": "iteration_time_no_drop",
        "mean": float(sim.T.mean()), "std": float(sim.T.std()),
        "p50": float(np.median(sim.T)), "p99": float(np.quantile(sim.T, 0.99)),
    }]

    # thresholds by drop-rate target (like the figure's 2.5% / 5% / 10%)
    for target in (0.025, 0.05, 0.10):
        # invert: find tau such that mean completed fraction = 1 - target
        grid = np.linspace(sim.T_n.mean() * 0.6, sim.T.max(), 400)
        fracs = np.array([sim.with_threshold(t)[1].mean() for t in grid])
        tau = float(grid[np.argmin(np.abs(fracs - (1 - target)))])
        t_iter, frac = sim.with_threshold(tau)
        rows.append({
            "setting": f"iteration_time_drop_{target:.1%}",
            "mean": float(t_iter.mean()), "std": float(t_iter.std()),
            "p50": float(np.median(t_iter)), "p99": float(np.quantile(t_iter, 0.99)),
        })

    write_rows("fig2_variance", rows)
    base = rows[1]
    d10 = rows[-1]
    return [
        {"name": "fig2/iter_std_no_drop", "value": round(base["std"], 4)},
        {"name": "fig2/iter_std_drop10pct", "value": round(d10["std"], 4)},
        {"name": "fig2/iter_mean_reduction_10pct", "value": round(1 - d10["mean"] / base["mean"], 4)},
    ]
