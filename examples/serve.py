"""Serve a small model with the chunked-prefill continuous batcher.

    PYTHONPATH=src python examples/serve.py --batch 8 --new-tokens 32 \
        --chunk-size 16 --token-budget 48

Initializes a small decoder and pushes a stream of requests through
``ContinuousBatcher``: prompts are prefilled ``--chunk-size`` tokens per
engine step, and each step's total work is capped at ``--token-budget``
scheduled tokens — the serving analogue of DropCompute's compute
threshold ``tau`` (overflow prefill chunks are deferred, decode slots
never stall).  ``--chunk-size 1`` reproduces the seed token-streaming
behaviour for comparison.
"""
import argparse
import os
import sys
import time

import jax

# the seeded workload helpers live with the benchmarks (one generator,
# one seed convention — benchmarks and examples replay identical sets)
sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                    os.pardir, "benchmarks")
)
from common import make_requests  # noqa: E402

from repro.models import ModelConfig
from repro.models.model import init_params
from repro.serve import (
    ContinuousBatcher,
    DraftModelProposer,
    NGramProposer,
    SamplingParams,
    SpecConfig,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8, help="cache slots")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--token-budget", type=int, default=0,
                    help="per-step scheduled-token cap (0 = uncapped)")
    ap.add_argument("--packed", action="store_true",
                    help="token-packed step program: granted tokens alone "
                         "determine per-step compute")
    ap.add_argument("--cache", default="dense", choices=["dense", "paged"],
                    help="KV-cache layout (repro.serve.kv): paged = page "
                         "pool + block tables + prefix sharing")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-dtype", default="", metavar="DTYPE",
                    help="paged-pool element type (e.g. 'int8': quantized "
                         "pages with per-row scales — about half the bytes "
                         "per page, so a fixed HBM budget holds ~2x the "
                         "pages; outputs are allclose to dense, not "
                         "bit-identical). Default: the model compute dtype")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="give every prompt the same N-token prefix; with "
                         "--cache paged, later requests map the first "
                         "one's pages instead of re-prefilling them. "
                         "Sharing needs the prefix pages to be fully "
                         "written first, so it kicks in for requests that "
                         "trail an earlier one (queued past the slot "
                         "count, or budget-staggered) — slots prefilling "
                         "the same prefix in lockstep each write their "
                         "own copy")
    ap.add_argument("--spec", default="off", choices=["off", "ngram", "draft"],
                    help="speculative decoding: 'ngram' proposes from each "
                         "request's own token history (prompt-lookup), "
                         "'draft' runs a smaller draft model ahead; the "
                         "target verifies k tokens per decode step and "
                         "output stays token-identical to plain greedy")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens verified per decode slot per step")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy; with --spec, "
                         "rejection-sampling verification keeps the sampled "
                         "stream identical to no-spec decoding)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus truncation (1.0 = off)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base sampling seed; request i streams from "
                         "seed+i, so reruns are reproducible")
    ap.add_argument("--capacity-factor", type=float, default=0.0,
                    help="MoE serving dispatch (needs an MoE --arch, e.g. "
                         "moe_tiny or mixtral-8x22b): route tokens through "
                         "fixed per-expert buffers of ceil(cf * tokens * "
                         "top_k / n_experts) slots; overflow routes drop to "
                         "the residual path — per-expert tau.  0 = dense "
                         "dispatch (every token through every chosen "
                         "expert); inf = never drop, byte-identical to "
                         "dense")
    ap.add_argument("--arch", default="",
                    help="optional smoke-config name — any pattern serves "
                         "through this engine now: attention "
                         "(qwen2.5-3b), MoE (mixtral-8x22b, moe_tiny), "
                         "SSD (mamba2-130m, mamba2_tiny), RG-LRU hybrid "
                         "(recurrentgemma-2b, hybrid_tiny)")
    args = ap.parse_args()

    if args.arch:
        from repro.configs import get_smoke_config

        cfg = get_smoke_config(args.arch)
    else:
        cfg = ModelConfig(name="serve-demo", n_layers=4, d_model=128, n_heads=4,
                          n_kv_heads=2, d_ff=256, vocab_size=1003,
                          sliding_window=64, layer_pattern="LG", dtype="float32",
                          remat=False)
    print(f"serving {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    params = init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.new_tokens
    spec = None
    if args.spec == "ngram":
        spec = SpecConfig(NGramProposer(), k=args.spec_k)
    elif args.spec == "draft":
        # demo draft: a half-width model (random weights, so expect low
        # acceptance — a real deployment distills or shrinks the target)
        dcfg = ModelConfig(name="serve-draft", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=128,
                           vocab_size=cfg.vocab_size, sliding_window=64,
                           layer_pattern="LG", dtype="float32", remat=False)
        dparams = init_params(jax.random.PRNGKey(1), dcfg)
        spec = SpecConfig(
            DraftModelProposer(dparams, dcfg, args.batch, max_len),
            k=args.spec_k,
        )
    eng = ContinuousBatcher(
        params, cfg, batch_slots=args.batch, max_len=max_len,
        chunk_size=args.chunk_size,
        token_budget=args.token_budget or None,
        packed=args.packed,
        cache=args.cache, page_size=args.page_size,
        kv_dtype=args.kv_dtype or None,
        spec=spec,
        capacity_factor=args.capacity_factor or None,
    )

    sampling = None
    if args.temperature > 0:
        sampling = SamplingParams(temperature=args.temperature,
                                  top_k=args.top_k, top_p=args.top_p,
                                  seed=args.sample_seed)
        print(f"  sampling: T={args.temperature} top_k={args.top_k} "
              f"top_p={args.top_p} base seed {args.sample_seed}")
    for req in make_requests(args.requests, args.prompt_len, args.new_tokens,
                             cfg.vocab_size, seed=1,
                             shared_prefix=args.shared_prefix,
                             sampling=sampling):
        eng.submit(req)

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0

    s = eng.stats_summary()
    n_out = sum(len(r.output) for r in done.values())
    n_prompt = args.requests * args.prompt_len
    print(f"finished {len(done)}/{args.requests} requests in {dt:.2f}s "
          f"({eng.steps} engine steps)")
    print(f"  prompt tokens {n_prompt}  output tokens {n_out}  "
          f"total {(n_prompt + n_out)/dt:.1f} tok/s")
    print(f"  mean TTFT {s['mean_ttft']*1e3:.1f} ms   p99 TTFT {s['p99_ttft']*1e3:.1f} ms")
    print(f"  max step tokens {s['max_step_tokens']:.0f}  "
          f"deferred {s['deferred_tokens']:.0f}  "
          f"max step wall {s['max_step_wall']*1e3:.1f} ms")
    if eng.kv is not None:
        print(f"  paged KV: {s['peak_used_pages']:.0f}/{s['num_pages']:.0f} "
              f"peak pages used ({args.page_size} tokens each), "
              f"{s['shared_tokens']:.0f} prompt tokens served from "
              f"prefix-shared pages")
    if eng.spec is not None:
        print(f"  speculative ({args.spec}, k={args.spec_k}): "
              f"{s['draft_tokens']:.0f} drafts verified, acceptance "
              f"{s['acceptance_rate']:.2f}, "
              f"{s['steps_per_token']:.2f} engine steps per generated token")
    if args.capacity_factor:
        print(f"  MoE capacity dispatch (cf={args.capacity_factor}): "
              f"{s['expert_overflow_tokens']:.0f} routes dropped to the "
              f"residual path (max {s['max_expert_overflow']:.0f}/step)")
    r0 = done[0]
    print("sample continuation:", r0.output[:12])


if __name__ == "__main__":
    main()
