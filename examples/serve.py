"""Serve a small model: batched greedy decoding over a KV cache.

    PYTHONPATH=src python examples/serve.py --batch 8 --new-tokens 32

Initializes a small decoder, "prefills" a batch of prompts token by token
into the cache, then decodes new tokens for the whole batch in lockstep —
the same ``decode_step`` the decode_32k / long_500k dry-run shapes lower.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import ModelConfig
from repro.models.model import decode_step, init_decode_cache, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--arch", default="",
                    help="optional smoke-config name (e.g. mixtral-8x22b)")
    args = ap.parse_args()

    if args.arch:
        from repro.configs import get_smoke_config

        cfg = get_smoke_config(args.arch)
    else:
        cfg = ModelConfig(name="serve-demo", n_layers=4, d_model=128, n_heads=4,
                          n_kv_heads=2, d_ff=256, vocab_size=1003,
                          sliding_window=64, layer_pattern="LG", dtype="float32",
                          remat=False)
    print(f"serving {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    params = init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.new_tokens
    cache = init_decode_cache(params, cfg, args.batch, max_len)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos, moe_impl="dense"))

    # prefill (token-by-token through the decode path)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, t : t + 1], jnp.int32(t))
    jax.block_until_ready(logits)
    print(f"prefill {args.prompt_len} tokens x {args.batch} seqs: {time.time()-t0:.2f}s")

    # decode
    tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for t in range(args.prompt_len, max_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    n = len(out) * args.batch
    print(f"decoded {n} tokens in {dt:.2f}s  ({n/dt:.1f} tok/s batched)")
    print("sample continuation:", [int(t[0, 0]) for t in out[:12]])


if __name__ == "__main__":
    main()
