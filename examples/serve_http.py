"""OpenAI-style HTTP completions endpoint over the async front-end.

    PYTHONPATH=src python examples/serve_http.py --port 8000

then::

    curl -s localhost:8000/v1/completions -d \
        '{"prompt": [3, 1, 4, 1, 5], "max_tokens": 16}'
    curl -sN localhost:8000/v1/completions -d \
        '{"prompt": [3, 1, 4, 1, 5], "max_tokens": 16, "stream": true}'

Request body fields (OpenAI completions shape): ``prompt`` (list of
token ids, or a string through the demo hasher), ``max_tokens``,
``stream``, and the sampling knobs ``temperature`` (float >= 0, default
0 = greedy), ``top_k`` (int >= 0, 0 = off), ``top_p`` (float in (0, 1],
1 = off), ``seed`` (int).  Sampling is per-request and reproducible:
the same ``seed`` + params replays the identical token stream; when
``temperature > 0`` and no ``seed`` is given the server assigns one
from a monotone counter (echoed back as ``"seed"`` in the response) so
concurrent requests never share a stream.  Invalid sampling params are
HTTP 400.

Everything is stdlib: ``asyncio.start_server`` plus a small HTTP/1.1
shim — no web framework in the image, none needed.  One
:class:`repro.serve.AsyncEngine` serves every connection; requests
stream tokens back as server-sent events (``"stream": true``, one
``data:`` chunk per token, ``data: [DONE]`` terminator — the OpenAI
wire shape) or buffer into a single JSON body.  Engine overload surfaces
as HTTP 429 (``AdmissionError`` from the waiting room), bad requests as
HTTP 400, and a TTFT deadline (``--deadline``) as a 503 with the
request's lifecycle events attached.

``GET /v1/stats`` returns the live ``stats_summary``;
``--self-test`` starts the server, exercises all of the above against
it through a raw socket client, and exits (used by CI).

The demo model has no tokenizer, so ``prompt`` is a list of token ids
(a JSON string is hashed per-character into ids — good enough to play
with streaming, not a real tokenizer).
"""
import argparse
import asyncio
import itertools
import json
import time

import jax

from repro.models import ModelConfig
from repro.models.model import init_params
from repro.serve import (
    AdmissionError,
    AsyncEngine,
    ContinuousBatcher,
    InvalidRequestError,
    SamplingParams,
)


def build_engine(args):
    cfg = ModelConfig(name="serve-http", n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab_size=1003,
                      sliding_window=64, layer_pattern="LG", dtype="float32",
                      remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatcher(
        params, cfg, batch_slots=args.batch, max_len=args.max_len,
        chunk_size=args.chunk, token_budget=args.token_budget or None,
        packed=True, cache="paged", page_size=16,
        max_queue=args.batch * 2,
    )
    return eng, cfg


def ids_from_prompt(prompt, vocab):
    """Token ids from the request ``prompt`` field: a list of ints is
    used as-is; a string is per-character hashed (demo stand-in for a
    tokenizer)."""
    if isinstance(prompt, str):
        if not prompt:
            raise ValueError("empty prompt")
        return [(ord(c) * 2654435761) % vocab for c in prompt]
    if (isinstance(prompt, list) and prompt
            and all(isinstance(t, int) for t in prompt)):
        return prompt
    raise ValueError("prompt must be a non-empty string or list of ints")


# ---------------------------------------------------------------------------
# minimal HTTP/1.1 on asyncio streams
# ---------------------------------------------------------------------------


async def read_request(reader):
    """Parse one request; returns (method, path, body_bytes) or None on
    a closed/garbled connection."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
        return None
    lines = head.decode("latin-1").split("\r\n")
    try:
        method, path, _ = lines[0].split(" ", 2)
    except ValueError:
        return None
    length = 0
    for line in lines[1:]:
        if line.lower().startswith("content-length:"):
            length = int(line.split(":", 1)[1])
    body = await reader.readexactly(length) if length else b""
    return method, path, body


def http_response(status, payload, *, ctype="application/json"):
    body = (json.dumps(payload).encode()
            if not isinstance(payload, bytes) else payload)
    return (f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode() + body


STATUS = {200: "200 OK", 400: "400 Bad Request", 404: "404 Not Found",
          429: "429 Too Many Requests", 503: "503 Service Unavailable"}


class Server:
    def __init__(self, frontend, cfg, *, deadline=None, default_max=32):
        self.fe = frontend
        self.cfg = cfg
        self.deadline = deadline
        self.default_max = default_max
        # auto-assigned seeds for sampled requests that don't send one:
        # a counter, not entropy, so server logs alone replay any stream
        self._auto_seed = itertools.count(1)

    def _sampling(self, spec):
        """(SamplingParams or None, effective seed or None) from request
        fields; raises ValueError (-> 400) on invalid params."""
        temperature = float(spec.get("temperature", 0.0))
        top_k = spec.get("top_k", 0)
        top_p = float(spec.get("top_p", 1.0))
        if temperature == 0.0 and top_k == 0 and top_p == 1.0 \
                and "seed" not in spec:
            return None, None
        seed = spec.get("seed")
        if seed is None:
            seed = next(self._auto_seed)
        return SamplingParams(temperature=temperature, top_k=top_k,
                              top_p=top_p, seed=seed), seed

    async def handle(self, reader, writer):
        try:
            parsed = await read_request(reader)
            if parsed is None:
                return
            method, path, body = parsed
            if path == "/v1/stats" and method == "GET":
                writer.write(http_response(STATUS[200], self.fe.summary()))
            elif path == "/v1/completions" and method == "POST":
                await self.completions(writer, body)
            else:
                writer.write(http_response(STATUS[404],
                                           {"error": f"no route {path}"}))
            await writer.drain()
        finally:
            writer.close()

    async def completions(self, writer, body):
        try:
            spec = json.loads(body or b"{}")
            ids = ids_from_prompt(spec.get("prompt"), self.cfg.vocab_size)
            max_tokens = int(spec.get("max_tokens", self.default_max))
            sampling, seed = self._sampling(spec)
            stream = await self.fe.submit(ids, max_tokens,
                                          deadline_s=self.deadline,
                                          sampling=sampling)
        except (TypeError, ValueError, InvalidRequestError) as e:
            writer.write(http_response(STATUS[400], {"error": str(e)}))
            return
        except AdmissionError as e:
            writer.write(http_response(
                STATUS[429], {"error": f"overloaded: {e}"}))
            return

        created = int(time.time())
        if spec.get("stream"):
            writer.write(
                b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n"
                b"Cache-Control: no-cache\r\nConnection: close\r\n\r\n"
            )
            async for tok in stream:
                chunk = {"id": f"cmpl-{stream.uid}", "object": "completion",
                         "created": created,
                         "choices": [{"index": 0, "text": f" {tok}",
                                      "token": tok, "finish_reason": None}]}
                writer.write(f"data: {json.dumps(chunk)}\n\n".encode())
                await writer.drain()
            writer.write(b"data: [DONE]\n\n")
        else:
            await stream.collect()
            if stream.status != "finished":
                writer.write(http_response(STATUS[503], {
                    "error": f"request {stream.status}",
                    "events": [[e.kind, e.detail] for e in stream.events],
                }))
                return
            writer.write(http_response(STATUS[200], {
                "id": f"cmpl-{stream.uid}", "object": "completion",
                "created": created, "model": self.cfg.name,
                **({"seed": seed} if seed is not None else {}),
                "choices": [{
                    "index": 0,
                    "text": " ".join(str(t) for t in stream.tokens),
                    "tokens": stream.tokens,
                    "finish_reason": ("length" if stream.truncated
                                      else "stop"),
                }],
                "usage": {
                    "prompt_tokens": len(stream.request.prompt),
                    "completion_tokens": len(stream.tokens),
                    "ttft_ms": round(stream.ttft * 1e3, 2),
                },
            }))


# ---------------------------------------------------------------------------
# self-test client (raw sockets; also the CI smoke)
# ---------------------------------------------------------------------------


async def http_call(port, method, path, payload=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode() if payload is not None else b""
    writer.write(
        (f"{method} {path} HTTP/1.1\r\nHost: x\r\n"
         f"Content-Length: {len(body)}\r\n\r\n").encode() + body
    )
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, rbody = raw.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    return status, rbody


async def self_test(port, cfg):
    # non-streaming completion
    status, body = await http_call(port, "POST", "/v1/completions",
                                   {"prompt": [3, 1, 4, 1, 5],
                                    "max_tokens": 8})
    assert status == 200, (status, body)
    out = json.loads(body)
    toks = out["choices"][0]["tokens"]
    assert len(toks) == 8 and out["usage"]["prompt_tokens"] == 5, out
    # string prompt goes through the demo hasher
    status, body = await http_call(port, "POST", "/v1/completions",
                                   {"prompt": "hello", "max_tokens": 4})
    assert status == 200 and len(json.loads(body)["choices"][0]["tokens"]) == 4
    # streaming: SSE chunks, one per token, [DONE]-terminated, same tokens
    status, body = await http_call(port, "POST", "/v1/completions",
                                   {"prompt": [3, 1, 4, 1, 5],
                                    "max_tokens": 8, "stream": True})
    assert status == 200, (status, body)
    events = [line[len(b"data: "):] for line in body.split(b"\n\n")
              if line.startswith(b"data: ")]
    assert events[-1] == b"[DONE]" and len(events) == 9, events
    streamed = [json.loads(e)["choices"][0]["token"] for e in events[:-1]]
    assert streamed == toks, (streamed, toks)
    # sampled completions: same seed -> identical stream (reproducible),
    # different seed -> different stream, seed echoed when auto-assigned
    sampled = {"prompt": [3, 1, 4, 1, 5], "max_tokens": 8,
               "temperature": 0.8, "top_p": 0.95, "seed": 42}
    _, b1 = await http_call(port, "POST", "/v1/completions", sampled)
    _, b2 = await http_call(port, "POST", "/v1/completions", sampled)
    t1 = json.loads(b1)["choices"][0]["tokens"]
    assert t1 == json.loads(b2)["choices"][0]["tokens"], (b1, b2)
    assert json.loads(b1)["seed"] == 42
    _, b3 = await http_call(port, "POST", "/v1/completions",
                            {**sampled, "seed": 43})
    assert json.loads(b3)["choices"][0]["tokens"] != t1
    status, b4 = await http_call(port, "POST", "/v1/completions",
                                 {k: v for k, v in sampled.items()
                                  if k != "seed"})
    assert status == 200 and isinstance(json.loads(b4)["seed"], int)
    # bad requests (including invalid sampling params)
    for bad in ({"prompt": [], "max_tokens": 4},
                {"prompt": [1, 2], "max_tokens": 0},
                {"prompt": "x" * 10_000, "max_tokens": 4},
                {"prompt": [1, 2], "max_tokens": 4, "temperature": -1.0},
                {"prompt": [1, 2], "max_tokens": 4, "top_p": 0.0},
                {"prompt": [1, 2], "max_tokens": 4, "top_k": -3},
                {"prompt": [1, 2], "max_tokens": 4, "temperature": "hot"}):
        status, _ = await http_call(port, "POST", "/v1/completions", bad)
        assert status == 400, (bad, status)
    status, _ = await http_call(port, "GET", "/v1/nope")
    assert status == 404
    status, body = await http_call(port, "GET", "/v1/stats")
    assert status == 200 and json.loads(body)["frontend_finished"] >= 3.0
    print("self-test OK: completions, streaming SSE, seeded sampling, "
          "errors, stats")


async def amain(args):
    eng, cfg = build_engine(args)
    fe = AsyncEngine(eng, waiting_room=args.waiting_room,
                     queue_timeout=args.queue_timeout or None)
    await fe.start()
    srv = Server(fe, cfg, deadline=args.deadline or None,
                 default_max=args.max_tokens)
    server = await asyncio.start_server(srv.handle, args.host, args.port)
    port = server.sockets[0].getsockname()[1]
    print(f"serving {cfg.name} on http://{args.host}:{port}/v1/completions "
          f"({args.batch} slots, paged KV)")
    try:
        if args.self_test:
            await self_test(port, cfg)
        else:
            async with server:
                await server.serve_forever()
    finally:
        server.close()
        await server.wait_closed()
        await fe.stop(drain=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="0 = pick a free port")
    ap.add_argument("--batch", type=int, default=8, help="cache slots")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--token-budget", type=int, default=96)
    ap.add_argument("--max-tokens", type=int, default=32,
                    help="default completion length")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="TTFT SLO in seconds (0 = none); missed -> 503")
    ap.add_argument("--waiting-room", type=int, default=64)
    ap.add_argument("--queue-timeout", type=float, default=0.0)
    ap.add_argument("--self-test", action="store_true",
                    help="start, exercise the endpoint, exit")
    args = ap.parse_args()
    asyncio.run(amain(args))


if __name__ == "__main__":
    main()
