"""End-to-end driver: train a ~110M-parameter LM with DropCompute.

    PYTHONPATH=src python examples/train_100m.py --steps 200

GPT2-small-ish decoder (12L, d=768, 12H, vocab 32k, ~110M params) on the
synthetic packed-token pipeline, 8 virtual workers x 4 accumulations with
the paper's simulated-delay environment, automatic threshold selection,
and periodic checkpointing.  CPU-friendly defaults; scale flags up on
real hardware.
"""
import argparse
import time

import numpy as np

from repro.core import DropConfig, PAPER_DELAY
from repro.data import DataConfig
from repro.models import ModelConfig
from repro.train import TrainConfig, train


def model_cfg(d_model=768, n_layers=12):
    return ModelConfig(
        name="lm-110m", n_layers=n_layers, d_model=d_model, n_heads=12,
        n_kv_heads=12, d_ff=4 * d_model, vocab_size=32000,
        layer_pattern="G", dtype="float32", remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--no-drop", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = model_cfg()
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_size=args.batch, strategy="pack")
    tcfg = TrainConfig(
        steps=args.steps, n_workers=args.workers, microbatches=args.microbatches,
        optimizer="adamw", lr=args.lr,
        drop=DropConfig(enabled=not args.no_drop, tau=float("inf")),
        auto_threshold=not args.no_drop, calibration_steps=20,
        latency=PAPER_DELAY, tc=0.5,
        ckpt_dir=args.ckpt or None, ckpt_every=50 if args.ckpt else 0,
        log_every=10,
    )
    t0 = time.time()
    r = train(cfg, data, tcfg)
    wall = time.time() - t0
    print(f"\nsteps={args.steps}  wall={wall:.0f}s  "
          f"loss {r.losses[0]:.3f} -> {r.losses[-1]:.3f}")
    if not args.no_drop:
        print(f"tau*={r.tau:.2f}s  drop={np.mean(r.drop_fractions):.1%}  "
              f"simulated cluster time {r.metrics['total_sim_time']:.0f}s")


if __name__ == "__main__":
    main()
