"""Quickstart: train a tiny LM with DropCompute and see the win.

    PYTHONPATH=src python examples/quickstart.py

Runs two short training sessions in the paper's simulated-delay
environment (appendix B.1) — vanilla synchronous vs DropCompute with the
automatically selected threshold (Algorithm 2) — and reports final loss,
drop rate and simulated wall-clock.
"""
import numpy as np

from repro.core import DropConfig, PAPER_DELAY
from repro.data import DataConfig
from repro.models import ModelConfig
from repro.train import TrainConfig, train

MODEL = ModelConfig(
    name="quickstart", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=251, dtype="float32", remat=False,
)
DATA = DataConfig(vocab_size=251, seq_len=64, batch_size=32, strategy="pack")


def main():
    common = dict(steps=40, n_workers=8, microbatches=4, lr=1e-3,
                  latency=PAPER_DELAY, tc=0.5, seed=0)

    print("== baseline (vanilla synchronous) ==")
    base = train(MODEL, DATA, TrainConfig(drop=DropConfig(enabled=False), **common))
    print(f"final loss {base.losses[-1]:.4f}   simulated time {base.metrics['total_sim_time']:.1f}s")

    print("\n== DropCompute (Algorithm 2 auto-threshold) ==")
    drop = train(MODEL, DATA, TrainConfig(
        drop=DropConfig(enabled=True, tau=float("inf")),
        auto_threshold=True, calibration_steps=10, **common))
    print(f"final loss {drop.losses[-1]:.4f}   simulated time {drop.metrics['total_sim_time']:.1f}s")
    print(f"tau* = {drop.tau:.2f}s   mean drop rate {np.mean(drop.drop_fractions):.1%}")
    print(f"\n>>> time saving {1 - drop.metrics['total_sim_time']/base.metrics['total_sim_time']:.1%} "
          f"at loss delta {drop.losses[-1] - base.losses[-1]:+.4f}")


if __name__ == "__main__":
    main()
