"""Cluster-scale what-if analysis: is DropCompute worth it on YOUR cluster?

    PYTHONPATH=src python examples/straggler_sim.py --workers 256 --noise lognormal
    PYTHONPATH=src python examples/straggler_sim.py --faults badnode --onset 100

Feeds a latency model (or swap in real measured micro-batch times) through
Algorithm 2 and the closed-form theory (§4) to report: expected iteration
time, E[T]/E[T_n] straggler ratio, tau*, and the scale curve.

``--faults`` layers a seeded ``repro.train.resilience`` fault scenario
(pareto / lognormal / badnode / stall / none) over the base model and
additionally replays the *online* tau controller against the stream —
showing how tau* moves when the cluster degrades mid-run, and what a
one-shot calibration would have missed.  Everything is deterministic in
``--seed``: rerunning prints identical numbers.
"""
import argparse

import numpy as np

from repro.core import (
    LatencyModel,
    NoiseModel,
    expected_step_time,
    optimal_tau,
    scale_curve,
    simulate,
)
from repro.core.simulate import SimResult
from repro.core.threshold import select_threshold
from repro.train.resilience import (
    SCENARIOS,
    ComputeTelemetry,
    ControllerConfig,
    TauController,
    make_scenario,
)


def _fault_report(model: LatencyModel, args) -> None:
    """Simulate the fault scenario and replay static vs online tau on it."""
    n, m, iters = args.workers, args.accumulations, args.iters
    lat = make_scenario(args.faults, base=model, seed=args.seed, onset=args.onset)
    t = np.stack([lat.sample_at(s, n, m, seed=args.seed) for s in range(iters)])

    pre, post = t[: args.onset], t[args.onset :]
    print(f"\nfault scenario '{args.faults}' (seed={args.seed}, onset={args.onset}):")
    for name, seg in (("pre-onset", pre), ("post-onset", post)):
        if not len(seg):
            continue
        res = select_threshold(seg, args.tc)
        print(f"  {name:10s}: E[T]={seg.sum(-1).max(-1).mean():6.2f}s  "
              f"tau*={res.tau:6.2f}s  S_eff={res.speedup:.4f}")

    # static: one-shot Algorithm 2 on the calibration prefix; online: the
    # TauController re-estimating from a rolling telemetry window
    calib = min(20, args.onset or 20)
    static_tau = select_threshold(t[:calib], args.tc).tau
    tel = ComputeTelemetry(n, m, window=32)
    ctl = TauController(ControllerConfig(warmup_steps=16, check_every=8),
                        tc=args.tc, total_steps=iters)
    for s in range(iters):
        tel.record(s, t[s], tau=ctl.tau)
        ctl.maybe_update(s, tel, steps_remaining=iters - s)
    print(f"  static (calibrated on first {calib} steps): tau = {static_tau:.2f}s")
    print("  online trajectory: "
          + " -> ".join(f"step {s}: tau={tau:.2f}" if np.isfinite(tau)
                        else f"step {s}: tau=inf"
                        for s, tau in ctl.trajectory))
    t_n = t.sum(axis=-1)
    sim_res = SimResult(t=t, T_n=t_n, T=t_n.max(axis=-1), tc=args.tc)
    for label, tau in (("static", static_tau), ("online", ctl.tau)):
        s_eff = sim_res.effective_speedup(tau)
        print(f"  S_eff over the full faulty run with {label} tau: {s_eff:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=200)
    ap.add_argument("--accumulations", type=int, default=12)
    ap.add_argument("--noise", default="paper_lognormal",
                    choices=["paper_lognormal", "lognormal", "normal", "exponential", "gamma", "bernoulli"])
    ap.add_argument("--mean", type=float, default=0.5)
    ap.add_argument("--var", type=float, default=0.25)
    ap.add_argument("--tc", type=float, default=0.5)
    ap.add_argument("--faults", default="",
                    choices=[""] + sorted(SCENARIOS),
                    help="layer a resilience fault scenario over the model")
    ap.add_argument("--onset", type=int, default=100,
                    help="step where mid-run faults (ramp/badnode) begin")
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = LatencyModel(base=0.45, noise=NoiseModel(kind=args.noise, mean=args.mean, var=args.var))
    n, m = args.workers, args.accumulations

    sim = simulate(model, args.iters, n, m, tc=args.tc, seed=args.seed)
    print(f"workers={n} accumulations={m} noise={args.noise}")
    print(f"  E[T_n] (one worker) = {sim.T_n.mean():.2f}s")
    print(f"  E[T]  (slowest)     = {sim.T.mean():.2f}s   ratio {sim.T.mean()/sim.T_n.mean():.3f}")
    print(f"  theory E[T]         = {expected_step_time(model.mean, model.std, m, n, args.tc) - args.tc:.2f}s")

    res = select_threshold(sim.t, sim.tc)
    print(f"\nAlgorithm 2: {res.summary()}")
    tau_th, s_th = optimal_tau(model.mean, model.std, m, n, args.tc)
    print(f"closed-form:  tau*={tau_th:.2f}s  S_eff={s_th:.4f}")

    print("\nscale curve (efficiency vs linear):")
    curve_b = scale_curve(model, [8, 32, 128, n], m, args.tc, iters=100)
    curve_d = scale_curve(model, [8, 32, 128, n], m, args.tc, iters=100, tau=res.tau)
    for w in (8, 32, 128, n):
        print(f"  N={w:5d}: baseline {curve_b[w][1]:.3f}   dropcompute {curve_d[w][1]:.3f}")

    if args.faults:
        _fault_report(model, args)


if __name__ == "__main__":
    main()
