"""Cluster-scale what-if analysis: is DropCompute worth it on YOUR cluster?

    PYTHONPATH=src python examples/straggler_sim.py --workers 256 --noise lognormal

Feeds a latency model (or swap in real measured micro-batch times) through
Algorithm 2 and the closed-form theory (§4) to report: expected iteration
time, E[T]/E[T_n] straggler ratio, tau*, and the scale curve.
"""
import argparse

import numpy as np

from repro.core import (
    LatencyModel,
    NoiseModel,
    expected_step_time,
    optimal_tau,
    scale_curve,
    simulate,
)
from repro.core.threshold import select_threshold


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=200)
    ap.add_argument("--accumulations", type=int, default=12)
    ap.add_argument("--noise", default="paper_lognormal",
                    choices=["paper_lognormal", "lognormal", "normal", "exponential", "gamma", "bernoulli"])
    ap.add_argument("--mean", type=float, default=0.5)
    ap.add_argument("--var", type=float, default=0.25)
    ap.add_argument("--tc", type=float, default=0.5)
    args = ap.parse_args()

    model = LatencyModel(base=0.45, noise=NoiseModel(kind=args.noise, mean=args.mean, var=args.var))
    n, m = args.workers, args.accumulations

    sim = simulate(model, 200, n, m, tc=args.tc, seed=0)
    print(f"workers={n} accumulations={m} noise={args.noise}")
    print(f"  E[T_n] (one worker) = {sim.T_n.mean():.2f}s")
    print(f"  E[T]  (slowest)     = {sim.T.mean():.2f}s   ratio {sim.T.mean()/sim.T_n.mean():.3f}")
    print(f"  theory E[T]         = {expected_step_time(model.mean, model.std, m, n, args.tc) - args.tc:.2f}s")

    res = select_threshold(sim.t, sim.tc)
    print(f"\nAlgorithm 2: {res.summary()}")
    tau_th, s_th = optimal_tau(model.mean, model.std, m, n, args.tc)
    print(f"closed-form:  tau*={tau_th:.2f}s  S_eff={s_th:.4f}")

    print("\nscale curve (efficiency vs linear):")
    curve_b = scale_curve(model, [8, 32, 128, n], m, args.tc, iters=100)
    curve_d = scale_curve(model, [8, 32, 128, n], m, args.tc, iters=100, tau=res.tau)
    for w in (8, 32, 128, n):
        print(f"  N={w:5d}: baseline {curve_b[w][1]:.3f}   dropcompute {curve_d[w][1]:.3f}")


if __name__ == "__main__":
    main()
