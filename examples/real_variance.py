"""DropCompute with REAL wall-clock compute variance — no simulation.

    PYTHONPATH=src python examples/real_variance.py --steps 12

The data pipeline's 'pad' strategy produces log-normal document lengths
(appendix B.1's motivation): micro-batches genuinely cost different
amounts of compute.  We make the variance physical by slicing each padded
micro-batch to its true length bucket before the jitted grad step, then
run Algorithm 1 with the HostTimedEngine (real `time.perf_counter`
measurements, drop decision between accumulations) and Algorithm 2 on the
measured profile.  Reported speedup is real wall-clock on this machine.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DropConfig, HostTimedEngine, make_grad_fn
from repro.core.threshold import select_threshold
from repro.data import DataConfig, batch_at
from repro.models import ModelConfig
from repro.models.model import init_params, loss_fn
from repro.optim import adamw, apply_updates

MODEL = ModelConfig(
    name="realvar", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=1009, dtype="float32", remat=False,
)
# bucketed true lengths -> genuinely different compute per micro-batch
BUCKETS = (64, 128, 256, 512)


def microbatches(step, data_cfg, m):
    """M micro-batches whose sequence length follows the doc-length draw."""
    rng = np.random.default_rng(step)
    out = []
    for j in range(m):
        ln = int(rng.choice(BUCKETS, p=[0.4, 0.3, 0.2, 0.1]))
        b = batch_at(step * m + j, data_cfg, worker=0)
        out.append({
            "tokens": jnp.asarray(b["tokens"][:, :ln]),
            "weights": jnp.asarray(b["weights"][:, :ln]),
        })
    return out


class BucketedEngine(HostTimedEngine):
    """HostTimedEngine over a list of differently-shaped micro-batches."""

    def step_list(self, params, mbs):
        g_sum, loss_sum, w_sum = None, jnp.zeros(()), jnp.zeros(())
        lat, computed = [], 0
        t0 = time.perf_counter()
        for mb in mbs:
            if (self.cfg.enabled and computed >= self.cfg.min_microbatches
                    and (time.perf_counter() - t0) > self.cfg.tau):
                break
            tm0 = time.perf_counter()
            g, l, w = self._grad_fn(params, mb)
            jax.block_until_ready(l)
            lat.append(time.perf_counter() - tm0)
            if g_sum is None:
                g_sum, loss_sum, w_sum = g, l, w
            else:
                g_sum, loss_sum, w_sum = self._acc(g_sum, g, l, w, loss_sum, w_sum)
            computed += 1
        self.latency_log.append(lat)
        denom = jnp.maximum(w_sum, 1.0)
        grads = jax.tree.map(lambda g_: g_ / denom, g_sum)
        return grads, loss_sum / denom, computed


def run(tau, steps, m, data_cfg, label):
    params = init_params(jax.random.PRNGKey(0), MODEL)
    opt = adamw(1e-3)
    state = opt.init(params)
    eng = BucketedEngine(make_grad_fn(lambda p, mb: loss_fn(p, MODEL, mb)),
                         DropConfig(enabled=np.isfinite(tau), tau=tau))
    # warmup-compile every bucket shape once (excluded from timing)
    for ln in BUCKETS:
        b = batch_at(0, data_cfg)
        mb = {"tokens": jnp.asarray(b["tokens"][:, :ln]),
              "weights": jnp.asarray(b["weights"][:, :ln])}
        jax.block_until_ready(eng._grad_fn(params, mb)[1])

    t0 = time.perf_counter()
    losses, drops = [], 0
    for s in range(steps):
        mbs = microbatches(s, data_cfg, m)
        grads, loss, computed = eng.step_list(params, mbs)
        drops += m - computed
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
        losses.append(float(loss))
    wall = time.perf_counter() - t0
    print(f"{label:28s} wall {wall:6.1f}s  loss {losses[0]:.3f}->{losses[-1]:.3f}  "
          f"dropped {drops}/{steps*m} micro-batches")
    return wall, losses[-1], eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    data_cfg = DataConfig(vocab_size=MODEL.vocab_size, seq_len=512,
                          batch_size=args.batch, strategy="pack")

    w_base, l_base, eng = run(float("inf"), args.steps, args.microbatches, data_cfg,
                              "baseline (no drops)")

    prof = eng.profile()
    prof = np.nan_to_num(prof, nan=np.nanmean(prof))
    res = select_threshold(prof, tc=0.0)
    print(f"Algorithm 2 on measured profile: {res.summary()}")

    w_drop, l_drop, _ = run(res.tau, args.steps, args.microbatches, data_cfg,
                            f"DropCompute (tau={res.tau:.2f}s)")
    print(f"\n>>> REAL wall-clock saving {1 - w_drop / w_base:.1%} "
          f"(per-worker; the max-of-N effect multiplies this at scale) "
          f"at loss delta {l_drop - l_base:+.4f}")


if __name__ == "__main__":
    main()
