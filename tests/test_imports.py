"""Import sweep: every ``repro.*`` module must import.

A phantom dependency (a module importing a package that doesn't exist —
exactly how ``repro.dist`` was dead-referenced by ``launch/steps.py`` for
a while) can never land silently again: this walks the whole package and
imports each module in one subprocess.

A subprocess because ``repro.launch.dryrun`` mutates ``XLA_FLAGS`` at
import (512 fake devices) — that must not leak into this process or any
test that forks later.
"""
import os
import pkgutil
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def iter_repro_modules():
    sys.path.insert(0, SRC)
    try:
        import repro

        names = ["repro"]
        for m in pkgutil.walk_packages(repro.__path__, prefix="repro."):
            names.append(m.name)
        return sorted(names)
    finally:
        sys.path.remove(SRC)


def test_every_repro_module_imports():
    names = iter_repro_modules()
    # the sweep must actually see the package layers it is protecting
    for expected in ("repro.dist.sharding", "repro.launch.steps",
                     "repro.launch.dryrun", "repro.serve.scheduler",
                     "repro.train.trainer"):
        assert expected in names, names
    code = "import importlib\n" + "".join(
        f"importlib.import_module({n!r})\n" for n in names
    ) + f"print('OK', {len(names)})"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert f"OK {len(names)}" in out.stdout


def test_launch_mesh_shim_is_gone():
    """The deprecated ``repro.launch.mesh`` re-export shim has been
    removed (it spent one release cycle warning): importing it must fail
    cleanly while the real module, ``repro.dist.mesh``, keeps working."""
    code = (
        "try:\n"
        "    import repro.launch.mesh\n"
        "except ModuleNotFoundError:\n"
        "    pass\n"
        "else:\n"
        "    raise AssertionError('repro.launch.mesh still importable')\n"
        "import repro.dist.mesh\n"
        "print('SHIM GONE')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=120,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHIM GONE" in out.stdout
