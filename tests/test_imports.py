"""Import sweep: every ``repro.*`` module must import.

A phantom dependency (a module importing a package that doesn't exist —
exactly how ``repro.dist`` was dead-referenced by ``launch/steps.py`` for
a while) can never land silently again: this walks the whole package and
imports each module in one subprocess.

A subprocess because ``repro.launch.dryrun`` mutates ``XLA_FLAGS`` at
import (512 fake devices) — that must not leak into this process or any
test that forks later.
"""
import os
import pkgutil
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def iter_repro_modules():
    sys.path.insert(0, SRC)
    try:
        import repro

        names = ["repro"]
        for m in pkgutil.walk_packages(repro.__path__, prefix="repro."):
            names.append(m.name)
        return sorted(names)
    finally:
        sys.path.remove(SRC)


def test_every_repro_module_imports():
    names = iter_repro_modules()
    # the sweep must actually see the package layers it is protecting
    for expected in ("repro.dist.sharding", "repro.launch.steps",
                     "repro.launch.dryrun", "repro.serve.scheduler",
                     "repro.train.trainer"):
        assert expected in names, names
    code = "import importlib\n" + "".join(
        f"importlib.import_module({n!r})\n" for n in names
    ) + f"print('OK', {len(names)})"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert f"OK {len(names)}" in out.stdout


def test_launch_mesh_shim_warns_and_reexports():
    """``repro.launch.mesh`` is a deprecated re-export of
    ``repro.dist.mesh``: importing it must raise DeprecationWarning and
    the shimmed symbols must be the same objects (in a subprocess — the
    warning fires at first import only)."""
    code = (
        "import warnings\n"
        "with warnings.catch_warnings(record=True) as w:\n"
        "    warnings.simplefilter('always')\n"
        "    import repro.launch.mesh as shim\n"
        "assert any(issubclass(x.category, DeprecationWarning) for x in w), \\\n"
        "    [str(x.message) for x in w]\n"
        "import repro.dist.mesh as real\n"
        "for name in shim.__all__:\n"
        "    assert getattr(shim, name) is getattr(real, name), name\n"
        "print('SHIM OK')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=120,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHIM OK" in out.stdout
