"""Model-zoo serving: recurrent chunk-scan + MoE capacity-dispatch parity.

The generalized cache/step contract (``serve.kv.KVState``'s per-layer-kind
LayerState protocol) promises that 'R'/'M' recurrent patterns and MoE
configs serve through the *same* engine as attention, token-identically to
the single-token ``decode_step`` oracle.  This suite is that promise:

* engine outputs vs the decode oracle for ``mamba2_tiny`` / ``hybrid_tiny``
  across budgets {None, 4, 16} x {dense, packed} x {dense, paged} cache —
  with slot reuse (more requests than slots);
* recurrent-state lifecycle invariants: admission zeroes, fork copies,
  trim refuses, cancel + readmit does not leak state;
* MoE capacity-factor dispatch properties: cf=inf is *byte-identical* to
  dense dispatch, per-expert counts never exceed capacity, padding
  consumes no capacity, and the engine surfaces dropped routes as
  ``StepStats.expert_overflow``.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models import moe as MoE
from repro.serve import ContinuousBatcher, Request, UnsupportedPatternError
from repro.serve.kv import KVCacheSpec

MAX_LEN = 32
MAX_NEW = 4


def _params(name):
    cfg = get_config(name)
    return cfg, M.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def zoo():
    return {n: _params(n) for n in ("mamba2_tiny", "hybrid_tiny", "moe_tiny")}


def _prompts(cfg, n=5, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=k).tolist()
            for k in rng.integers(3, 12, size=n)]


def decode_oracle(params, cfg, prompt, max_new=MAX_NEW, max_len=MAX_LEN):
    """One request alone, token by token — the parity ground truth."""
    cache = M.init_decode_cache(params, cfg, 1, max_len, linear=True)
    cur, out = list(prompt), []
    for t in range(len(prompt) + max_new - 1):
        lg, cache = M.decode_step(
            params, cfg, cache, jnp.asarray([[cur[t]]], jnp.int32),
            jnp.asarray([t], jnp.int32))
        jax.block_until_ready(lg)
        if t >= len(prompt) - 1:
            nxt = int(np.argmax(np.asarray(lg)[0, 0]))
            cur.append(nxt)
            out.append(nxt)
    return out


@pytest.fixture(scope="module")
def oracle(zoo):
    refs = {}
    for name, (cfg, params) in zoo.items():
        refs[name] = {
            tuple(p): decode_oracle(params, cfg, p) for p in _prompts(cfg)
        }
    return refs


def run_engine(params, cfg, prompts, max_new=MAX_NEW, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("chunk_size", 4)
    eng = ContinuousBatcher(params, cfg, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
    done = eng.run()
    return eng, {u: r.output for u, r in done.items()}


class TestRecurrentEngineParity:
    """Engine == decode oracle for recurrent patterns, every step path."""

    @pytest.mark.parametrize("arch", ["mamba2_tiny", "hybrid_tiny"])
    @pytest.mark.parametrize("budget", [None, 4, 16])
    @pytest.mark.parametrize("packed", [False, True])
    def test_budget_matrix(self, zoo, oracle, arch, budget, packed):
        cfg, params = zoo[arch]
        prompts = _prompts(cfg)  # 5 prompts through 2 slots: slot reuse
        _, got = run_engine(params, cfg, prompts,
                            token_budget=budget, packed=packed)
        for i, p in enumerate(prompts):
            assert got[i] == oracle[arch][tuple(p)], (arch, budget, packed, i)

    @pytest.mark.parametrize("arch", ["mamba2_tiny", "hybrid_tiny"])
    def test_paged_cache(self, zoo, oracle, arch):
        cfg, params = zoo[arch]
        prompts = _prompts(cfg)
        _, got = run_engine(params, cfg, prompts, cache="paged", page_size=4)
        for i, p in enumerate(prompts):
            assert got[i] == oracle[arch][tuple(p)], (arch, i)


class TestRecurrentLifecycle:
    """Slot-indexed recurrent leaves: admit zeroes, fork copies, trim
    refuses, cancel does not leak state into the next tenant."""

    def _recurrent_leaves(self, data):
        from repro.serve.kv import _is_recurrent_path

        flat = jax.tree_util.tree_flatten_with_path(data)[0]
        return [(p, x) for p, x in flat if _is_recurrent_path(p)]

    def _poison(self, kv, slot, value):
        """Write ``value`` into every recurrent row of ``slot``."""
        import dataclasses as dc

        from repro.serve.kv import _is_recurrent_path, _path_has

        def leaf(path, x):
            if not _is_recurrent_path(path):
                return x
            if _path_has(path, ("groups",)):
                return x.at[:, slot].set(value)
            return x.at[slot].set(value)

        kv.state = dc.replace(
            kv.state,
            data=jax.tree_util.tree_map_with_path(leaf, kv.state.data))

    def test_admit_zeroes_fork_copies_trim_refuses(self, zoo):
        cfg, params = zoo["hybrid_tiny"]
        spec = KVCacheSpec(num_slots=2, max_len=MAX_LEN, layout="paged",
                           page_size=4)
        kv = spec.build(params, cfg)
        leaves = self._recurrent_leaves(kv.state.data)
        assert leaves, "hybrid pattern must carry recurrent leaves"

        self._poison(kv, 0, 7.0)
        assert kv.admit_slot(0, [1, 2, 3], 4) == 0  # nothing shareable
        for path, x in self._recurrent_leaves(kv.state.data):
            assert not np.asarray(x).any(), path  # admission zeroed slot 0

        self._poison(kv, 0, 3.0)
        kv.fork_slot(0, 1)
        for path, x in self._recurrent_leaves(kv.state.data):
            a = np.asarray(x)
            row0 = a[:, 0] if "groups" in str(path) else a[0]
            row1 = a[:, 1] if "groups" in str(path) else a[1]
            np.testing.assert_array_equal(row0, row1)  # eager copy, no COW

        with pytest.raises(UnsupportedPatternError, match="roll back"):
            kv.trim_slot(0, 2)

    def test_prefix_sharing_disabled(self, zoo):
        cfg, params = zoo["hybrid_tiny"]
        spec = KVCacheSpec(num_slots=2, max_len=MAX_LEN, layout="paged",
                           page_size=2)
        kv = spec.build(params, cfg)
        prompt = list(range(10))
        kv.admit_slot(0, prompt, 4)
        # fully-written prompt pages would normally publish for sharing
        kv.register_prompt_pages(0, prompt, len(prompt))
        assert kv.probe_shared(prompt) == 0
        assert kv.admit_slot(1, prompt, 4) == 0  # nothing got shared

    def test_cancel_then_readmit_matches_oracle(self, zoo, oracle):
        cfg, params = zoo["mamba2_tiny"]
        prompts = _prompts(cfg)
        eng = ContinuousBatcher(params, cfg, batch_slots=2, max_len=MAX_LEN,
                                chunk_size=4)
        # run a victim a few steps, cancel it mid-flight, then serve the
        # real workload through the (recycled) slots
        eng.submit(Request(uid=99, prompt=prompts[0], max_new_tokens=8))
        eng.step()
        eng.step()
        assert eng.cancel(99)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=MAX_NEW))
        done = eng.run()
        for i, p in enumerate(prompts):
            assert done[i].output == oracle["mamba2_tiny"][tuple(p)], i


class TestMoECapacityDispatch:
    """Property tests for ``models.moe.apply_moe_capacity``."""

    @pytest.fixture(scope="class")
    def moe(self, zoo):
        cfg, _ = zoo["moe_tiny"]
        p = MoE.init_moe(jax.random.PRNGKey(3), cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
        return cfg, p, x

    def test_cf_inf_byte_identical_to_dense(self, moe):
        import dataclasses as dc

        cfg, p, x = moe
        cfg_inf = dc.replace(cfg, capacity_factor=math.inf)
        yd, _ = MoE.apply_moe_dense(p, x, cfg)
        yc, _, ovf = MoE.apply_moe_capacity(p, x, cfg_inf)
        assert int(ovf) == 0
        np.testing.assert_array_equal(np.asarray(yd), np.asarray(yc))

    def test_counts_never_exceed_capacity(self, moe):
        import dataclasses as dc

        cfg, p, x = moe
        t = x.shape[0] * x.shape[1]
        for cf in (0.25, 0.5, 1.0):
            cfg_c = dc.replace(cfg, capacity_factor=cf)
            cap = min(max(math.ceil(t * cfg.top_k / cfg.n_experts * cf), 1), t)
            _, top_i, _ = MoE._router(p, x.reshape(t, -1), cfg)
            counts = np.bincount(np.asarray(top_i).ravel(),
                                 minlength=cfg.n_experts)
            expect_drop = int(np.maximum(counts - cap, 0).sum())
            _, _, ovf = MoE.apply_moe_capacity(p, x, cfg_c)
            # overflow is exactly the per-expert excess over capacity
            assert int(ovf) == expect_drop, (cf, cap, counts)

    def test_padding_consumes_no_capacity(self, moe):
        import dataclasses as dc

        cfg, p, x = moe
        b, s, d = x.shape
        cfg_c = dc.replace(cfg, capacity_factor=0.5)
        # mask the tail half of every row; a padded call must equal the
        # same dispatch over only the valid tokens (capacity is computed
        # over the static shape, so equalize t by padding the short one)
        valid = jnp.arange(s)[None, :] < jnp.asarray([s // 2, s // 2])[:, None]
        y_pad, _, ovf_pad = MoE.apply_moe_capacity(p, x, cfg_c, valid=valid)
        y_np = np.asarray(y_pad)
        # invalid rows contribute exactly nothing
        assert not y_np[:, s // 2:].any()
        x_trim = jnp.concatenate(
            [x[:, : s // 2], jnp.zeros_like(x[:, s // 2:])], axis=1)
        y_trim, _, ovf_trim = MoE.apply_moe_capacity(
            p, x_trim, cfg_c, valid=valid)
        np.testing.assert_array_equal(y_np[:, : s // 2],
                                      np.asarray(y_trim)[:, : s // 2])
        assert int(ovf_pad) == int(ovf_trim)

    def test_engine_cf_inf_matches_oracle_and_counts_overflow(
            self, zoo, oracle):
        cfg, params = zoo["moe_tiny"]
        prompts = _prompts(cfg)
        for packed in (False, True):
            _, got = run_engine(params, cfg, prompts,
                                capacity_factor=math.inf, packed=packed)
            for i, p in enumerate(prompts):
                assert got[i] == oracle["moe_tiny"][tuple(p)], (packed, i)
        eng, _ = run_engine(params, cfg, prompts, capacity_factor=0.25)
        s = eng.stats_summary()
        assert s["expert_overflow_tokens"] > 0
        assert s["expert_overflow_tokens"] == sum(
            st.expert_overflow for st in eng.step_stats)

    def test_capacity_factor_requires_experts(self, zoo):
        cfg, params = zoo["mamba2_tiny"]
        with pytest.raises(ValueError, match="n_experts"):
            ContinuousBatcher(params, cfg, batch_slots=1, max_len=8,
                              capacity_factor=1.0)
