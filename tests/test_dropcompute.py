"""Unit tests for the DropCompute core (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DropConfig,
    HostTimedEngine,
    InGraphEngine,
    accumulate_grads,
    drop_mask,
    make_grad_fn,
)


def quad_loss(params, mb):
    # sum-of-squares regression: loss_sum over examples, weight = count
    x, y = mb["x"], mb["y"]
    pred = x @ params["w"]
    return jnp.sum((pred - y) ** 2), jnp.asarray(x.shape[0], jnp.float32)


def make_data(m=6, n=4, d=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, n, d)).astype(np.float32)
    y = rng.normal(size=(m, n)).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def params0(d=3):
    return {"w": jnp.zeros((d,), jnp.float32)}


class TestDropMask:
    def test_cumulative_semantics(self):
        lat = jnp.array([1.0, 1.0, 1.0, 1.0])
        np.testing.assert_array_equal(drop_mask(lat, 2.5), [1, 1, 0, 0])

    def test_inf_keeps_all(self):
        lat = jnp.ones((8,)) * 5
        assert float(drop_mask(lat, np.inf).sum()) == 8

    def test_min_microbatches(self):
        lat = jnp.ones((4,)) * 100
        m = drop_mask(lat, 0.5, min_microbatches=2)
        np.testing.assert_array_equal(m, [1, 1, 0, 0])

    def test_per_worker_rows(self):
        lat = jnp.array([[1.0, 1.0], [10.0, 1.0]])
        m = drop_mask(lat, 1.5, min_microbatches=0)
        np.testing.assert_array_equal(m, [[1, 0], [0, 0]])


class TestAccumulate:
    def test_tau_inf_equals_vanilla(self):
        mbs = make_data()
        gf = make_grad_fn(quad_loss)
        p = params0()
        mask = jnp.ones((6,))
        g1, l1, _ = accumulate_grads(gf, p, mbs, mask, DropConfig(tau=np.inf))
        # vanilla: single big batch mean
        xs = mbs["x"].reshape(-1, 3)
        ys = mbs["y"].reshape(-1)
        g_ref = jax.grad(lambda w: jnp.mean((xs @ w["w"] - ys) ** 2))(p)
        np.testing.assert_allclose(g1["w"], g_ref["w"], rtol=1e-5)

    def test_dropped_microbatches_excluded(self):
        mbs = make_data()
        gf = make_grad_fn(quad_loss)
        p = params0()
        mask = jnp.array([1, 1, 1, 0, 0, 0], jnp.float32)
        g, _, stats = accumulate_grads(gf, p, mbs, mask, DropConfig(normalize="computed"))
        kept = jax.tree.map(lambda a: a[:3], mbs)
        xs = kept["x"].reshape(-1, 3)
        ys = kept["y"].reshape(-1)
        g_ref = jax.grad(lambda w: jnp.mean((xs @ w["w"] - ys) ** 2))(p)
        np.testing.assert_allclose(g["w"], g_ref["w"], rtol=1e-5)
        assert float(stats["completed_fraction"]) == pytest.approx(0.5)

    def test_nominal_vs_computed_scaling(self):
        mbs = make_data()
        gf = make_grad_fn(quad_loss)
        p = params0()
        mask = jnp.array([1, 1, 1, 0, 0, 0], jnp.float32)
        g_c, _, _ = accumulate_grads(gf, p, mbs, mask, DropConfig(normalize="computed"))
        g_n, _, _ = accumulate_grads(gf, p, mbs, mask, DropConfig(normalize="nominal"))
        # nominal divides by the full batch => exactly half the magnitude here
        np.testing.assert_allclose(g_n["w"], g_c["w"] * 0.5, rtol=1e-5)


class TestEngines:
    def test_ingraph_matches_accumulate(self):
        mbs = make_data()
        cfg = DropConfig(tau=2.5)
        eng = InGraphEngine(make_grad_fn(quad_loss), cfg)
        lat = np.ones((6,), np.float32)
        g, loss, stats = eng.step(params0(), mbs, lat)
        assert float(stats["completed_microbatches"]) == 2
        g2, _, _ = accumulate_grads(
            make_grad_fn(quad_loss), params0(), mbs, drop_mask(jnp.asarray(lat), 2.5), cfg
        )
        np.testing.assert_allclose(g["w"], g2["w"], rtol=1e-6)

    def test_host_timed_engine_runs_and_profiles(self):
        cfg = DropConfig(tau=np.inf)
        eng = HostTimedEngine(make_grad_fn(quad_loss), cfg)
        g, loss, stats = eng.step(params0(), make_data())
        assert stats["completed_fraction"] == 1.0
        prof = eng.profile()
        assert prof.shape == (1, 1, 6)
        assert np.isfinite(prof).all()

    def test_host_timed_engine_drops_on_tiny_tau(self):
        cfg = DropConfig(tau=0.0, min_microbatches=1)
        eng = HostTimedEngine(make_grad_fn(quad_loss), cfg)
        g, loss, stats = eng.step(params0(), make_data())
        assert stats["completed_microbatches"] == 1.0
