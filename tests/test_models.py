"""Per-architecture smoke tests + model-math equivalences.

Every assigned architecture instantiates a REDUCED same-family variant,
runs one forward + one train step on CPU, and asserts output shapes and
no NaNs.  Decode-capable families also check decode == prefill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, PAPER_MODELS, get_config, get_smoke_config
from repro.models import INPUT_SHAPES, ModelConfig
from repro.models.model import (
    decode_step,
    encode,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
)
from repro.models.layers import causal_mask, sdpa, sdpa_flash, sdpa_local_banded

ALL = ARCHITECTURES + PAPER_MODELS

# Tier-1 runs a cheap representative subset per family; the full
# per-architecture sweep (incl. the big smoke configs) is the slow lane.
# (whisper_tiny's tier-1 coverage comes from test_decode_matches_prefill,
# which runs its full forward + enc-dec decode path.)
FAST_ARCHS = {"internlm2_1_8b", "internvl2_1b", "bert_large"}


def tiered(archs, fast=FAST_ARCHS):
    return [
        a if a in fast else pytest.param(a, marks=pytest.mark.slow)
        for a in archs
    ]


def make_batch(cfg, b=2, s=24, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "weights": jnp.ones((b, s), jnp.float32)}
    if cfg.prefix_len:
        batch["prefix"] = jnp.full((b, cfg.prefix_len, cfg.d_model), 0.01, jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.full((b, cfg.enc_seq, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", tiered(ALL))
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        p = init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)
        logits, aux = forward(p, cfg, batch)
        assert logits.shape == (2, 24, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        assert bool(jnp.isfinite(aux))

    def test_one_train_step_reduces_loss(self, arch):
        cfg = get_smoke_config(arch)
        p = init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)

        def loss(q):
            ls, w = loss_fn(q, cfg, batch)
            return ls / w

        l0, g = jax.value_and_grad(loss)(p)
        assert bool(jnp.isfinite(l0))
        gnorm = sum(float(jnp.sum(x.astype(jnp.float32) ** 2)) for x in jax.tree.leaves(g))
        assert np.isfinite(gnorm) and gnorm > 0
        p1 = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
        l1 = loss(p1)
        assert float(l1) < float(l0)


# no decode: encoder-only BERTs; internvl2's decode is text-only
# continuation (no patch prefix), so prefill/decode logits differ by design
DECODE_ARCHS = [a for a in ALL if a not in ("bert_large", "bert_1_5b", "internvl2_1b")]


@pytest.mark.parametrize("arch", tiered(DECODE_ARCHS, fast={"internlm2_1_8b"}))
def test_decode_matches_prefill(arch):
    cfg = get_smoke_config(arch)
    p = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, s=20)
    logits_full, _ = forward(p, cfg, batch, moe_impl="dense")
    enc_out = encode(p, cfg, batch["frames"]) if cfg.is_encdec else None
    cache = init_decode_cache(p, cfg, 2, 20, enc_out)
    step = jax.jit(lambda c, tok, pos: decode_step(p, cfg, c, tok, pos))
    outs = []
    for t in range(20):
        lg, cache = step(cache, batch["tokens"][:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full), atol=2e-4)


class TestFullConfigs:
    """The exact assigned configs (no allocation — abstract eval only)."""

    @pytest.mark.parametrize("arch", ARCHITECTURES)
    def test_exact_config_validates(self, arch):
        cfg = get_config(arch)
        cfg.validate()
        abs_params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        n = sum(np.prod(x.shape) for x in jax.tree.leaves(abs_params))
        assert n == cfg.param_count()

    def test_param_counts_match_model_cards(self):
        # coarse: within 25% of the nominal size in the name
        expect = {
            "mamba2-130m": 130e6,
            "internlm2-1.8b": 1.8e9,
            "recurrentgemma-2b": 2.6e9,  # +emb: RG-2B has 2.7B w/ embeddings
            "qwen2.5-3b": 3e9,
            "mixtral-8x22b": 141e9,
            "starcoder2-7b": 7e9,
            "qwen3-moe-235b-a22b": 235e9,
            "gemma3-27b": 27e9,
        }
        for arch, n in expect.items():
            got = get_config(arch).param_count()
            assert 0.7 * n < got < 1.35 * n, (arch, got, n)

    def test_moe_active_params(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        active = cfg.active_param_count()
        assert active < 0.2 * cfg.param_count()  # 22B active of 235B


class TestAttentionVariants:
    def setup_method(self):
        rng = jax.random.PRNGKey(0)
        self.q = jax.random.normal(rng, (2, 64, 4, 16))
        self.k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16))
        self.v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))

    def test_flash_matches_naive(self):
        ref = sdpa(self.q, self.k, self.v, causal_mask(64, 64))
        out = sdpa_flash(self.q, self.k, self.v, causal=True, q_chunk=16, k_chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_banded_matches_windowed(self):
        ref = sdpa(self.q, self.k, self.v, causal_mask(64, 64, window=16))
        out = sdpa_local_banded(self.q, self.k, self.v, window=16, block=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_flash_nondivisible_lengths(self):
        q = self.q[:, :50]
        k, v = self.k[:, :50], self.v[:, :50]
        ref = sdpa(q, k, v, causal_mask(50, 50))
        out = sdpa_flash(q, k, v, causal=True, q_chunk=16, k_chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


class TestInputShapes:
    def test_assigned_shapes_exact(self):
        assert INPUT_SHAPES["train_4k"].seq_len == 4096
        assert INPUT_SHAPES["train_4k"].global_batch == 256
        assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
        assert INPUT_SHAPES["prefill_32k"].global_batch == 32
        assert INPUT_SHAPES["decode_32k"].global_batch == 128
        assert INPUT_SHAPES["long_500k"].seq_len == 524288
        assert INPUT_SHAPES["long_500k"].global_batch == 1
