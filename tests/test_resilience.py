"""repro.train.resilience: telemetry, fault injection, the online tau
controller, and their wiring through the trainer (parity, adaptation,
checkpoint restore-parity)."""
import numpy as np
import pytest

from repro.core import DropConfig
from repro.core.simulate import LatencyModel, NoiseModel
from repro.core.threshold import fill_profile_nans, select_threshold
from repro.data import DataConfig
from repro.models import ModelConfig
from repro.train import TrainConfig, train
from repro.train.resilience import (
    BadNode,
    ComputeTelemetry,
    ControllerConfig,
    FaultyLatencyModel,
    P2Quantile,
    ParetoTail,
    RingBuffer,
    StreamingMoments,
    TauController,
    effective_speedup_at,
    make_scenario,
)

MILD = LatencyModel(base=0.45, noise=NoiseModel(kind="normal", mean=0.1, var=0.002))
TINY = ModelConfig(
    name="tiny", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
    vocab_size=131, dtype="float32", remat=False,
)
DATA = DataConfig(vocab_size=131, seq_len=32, batch_size=32, strategy="pack", seed=0)


def _feed(ctl, tel, latency, steps, n=8, m=8, seed=1):
    for s in range(steps):
        tel.record(s, latency.sample_at(s, n, m, seed=seed), tau=ctl.tau)
        ctl.maybe_update(s, tel, steps_remaining=steps - s)


class TestTelemetry:
    def test_ring_buffer_bound_and_order(self):
        rb = RingBuffer(4)
        for i in range(11):
            rb.push(float(i))
            assert len(rb) <= 4
        assert rb.window().tolist() == [7.0, 8.0, 9.0, 10.0]
        assert rb.total_pushed == 11

    def test_ring_buffer_shape_check(self):
        rb = RingBuffer(2, (3,))
        with pytest.raises(ValueError):
            rb.push(np.zeros(4))

    def test_streaming_moments_match_numpy(self):
        rng = np.random.default_rng(0)
        x = rng.lognormal(0.0, 1.0, size=500)
        sm = StreamingMoments()
        for chunk in np.split(x, 10):
            sm.push(chunk)
        assert sm.mean == pytest.approx(float(x.mean()), rel=1e-9)
        assert sm.std == pytest.approx(float(x.std()), rel=1e-9)

    def test_p2_quantile_approximates(self):
        rng = np.random.default_rng(1)
        x = rng.normal(10.0, 2.0, size=5000)
        p2 = P2Quantile(0.9)
        p2.push(x)
        assert p2.value == pytest.approx(float(np.quantile(x, 0.9)), rel=0.05)

    def test_record_validates_shape_and_summary(self):
        tel = ComputeTelemetry(2, 3, window=8)
        with pytest.raises(ValueError):
            tel.record(0, np.zeros((3, 2)))
        for s in range(12):
            tel.record(s, np.full((2, 3), 0.5))
        assert tel.steps == 12 and tel.window_size == 8
        summ = tel.summary()
        assert summ["mb_mean_s"] == pytest.approx(0.5)
        assert summ["worker_step_mean_s"] == pytest.approx(1.5)

    def test_state_roundtrip_preserves_window(self):
        tel = ComputeTelemetry(2, 2, window=4)
        rng = np.random.default_rng(2)
        for s in range(9):
            tel.record(s, rng.random((2, 2)))
        fresh = ComputeTelemetry(2, 2, window=4)
        fresh.load_state_dict(tel.state_dict())
        assert fresh.steps == tel.steps
        np.testing.assert_allclose(fresh.window(), tel.window())
        assert fresh.summary()["mb_mean_s"] == pytest.approx(tel.summary()["mb_mean_s"])

    def test_ingest_host_profile_fills_nans(self):
        prof = np.full((3, 1, 4), 0.5)
        prof[1, 0, 3] = np.nan  # a dropped micro-batch in the host log
        tel = ComputeTelemetry(2, 4, window=8)
        tel.ingest_host_profile(prof)
        assert tel.steps == 3
        assert np.isfinite(tel.window()).all()


class TestFaults:
    def test_deterministic_and_call_order_independent(self):
        a = make_scenario("pareto", seed=7)
        b = make_scenario("pareto", seed=7)
        t5 = a.sample_at(5, 4, 4)
        np.testing.assert_array_equal(t5, b.sample_at(5, 4, 4))
        b.sample_at(99, 4, 4)  # draws elsewhere must not shift step 5
        np.testing.assert_array_equal(t5, b.sample_at(5, 4, 4))

    def test_seed_changes_stream(self):
        a = make_scenario("pareto", seed=0)
        assert not np.array_equal(a.sample_at(3, 4, 4), a.sample_at(3, 4, 4, seed=1))

    def test_badnode_hits_only_its_rank_after_start(self):
        lat = FaultyLatencyModel(base=MILD, faults=(BadNode(rank=1, factor=3.0, start=10),))
        base = MILD.sample_at(5, 4, 4, seed=0)
        np.testing.assert_array_equal(lat.sample_at(5, 4, 4, seed=0), base)
        after = lat.sample_at(12, 4, 4, seed=0)
        base12 = MILD.sample_at(12, 4, 4, seed=0)
        np.testing.assert_allclose(after[1], base12[1] * 3.0)
        np.testing.assert_array_equal(np.delete(after, 1, 0), np.delete(base12, 1, 0))

    def test_host_delay_matches_perturbation(self):
        lat = make_scenario("badnode", seed=0, onset=0)
        for rank in range(4):
            d = lat.host_delay_at(3, rank, 4, 4)
            assert d >= 0.0
        # the bad rank's delay is the dominant one
        delays = [lat.host_delay_at(3, r, 8, 4) for r in range(8)]
        assert int(np.argmax(delays)) == 2  # SCENARIOS pins rank=2

    def test_onset_override_and_unknown_scenario(self):
        lat = make_scenario("badnode", seed=0, onset=50)
        assert lat.faults[0].start == 50
        with pytest.raises(ValueError):
            make_scenario("nope")


class TestController:
    def test_noop_on_mild_cluster(self):
        """No tail => S_eff ~ 1 everywhere => tau stays inf (the parity
        contract the trainer test pins end-to-end)."""
        tel = ComputeTelemetry(8, 8, window=32)
        ctl = TauController(ControllerConfig(warmup_steps=8, check_every=4), tc=0.5)
        _feed(ctl, tel, MILD, 60)
        assert not np.isfinite(ctl.tau)
        assert ctl.rebuilds == 0
        assert all(not d.applied for d in ctl.decisions)

    def test_applies_under_heavy_tail(self):
        tel = ComputeTelemetry(8, 8, window=32)
        ctl = TauController(ControllerConfig(warmup_steps=8, check_every=4), tc=0.5)
        _feed(ctl, tel, make_scenario("pareto", seed=0), 40)
        assert np.isfinite(ctl.tau)
        assert ctl.rebuilds >= 1
        assert ctl.trajectory[0] == (0, float("inf"))

    def test_gate_blocks_unamortizable_rebuild(self):
        """With a recompile cost no per-step gain can repay, tau never
        moves — however heavy the tail."""
        tel = ComputeTelemetry(8, 8, window=32)
        ctl = TauController(
            ControllerConfig(warmup_steps=8, check_every=4, recompile_cost_s=1e9),
            tc=0.5,
        )
        _feed(ctl, tel, make_scenario("pareto", seed=0), 60)
        assert not np.isfinite(ctl.tau)
        assert any(d.reason == "not_amortized" for d in ctl.decisions)

    def test_max_drop_guardrail(self):
        """The applied tau's completion respects 1 - max_drop."""
        tel = ComputeTelemetry(8, 8, window=32)
        cfg = ControllerConfig(warmup_steps=8, check_every=4, max_drop=0.25)
        ctl = TauController(cfg, tc=0.5)
        _feed(ctl, tel, make_scenario("pareto", seed=0), 40)
        assert np.isfinite(ctl.tau)
        _, completion = effective_speedup_at(tel.window(), 0.5, ctl.tau)
        assert completion >= 1.0 - cfg.max_drop - 0.05  # window drifts a little

    def test_state_roundtrip(self):
        tel = ComputeTelemetry(8, 8, window=32)
        ctl = TauController(ControllerConfig(warmup_steps=8, check_every=4), tc=0.5)
        _feed(ctl, tel, make_scenario("pareto", seed=0), 40)
        fresh = TauController(ctl.cfg, tc=0.5)
        fresh.load_state_dict(ctl.state_dict())
        assert fresh.tau == ctl.tau
        assert fresh.trajectory == ctl.trajectory
        assert fresh._last_check == ctl._last_check


class TestThresholdGuards:
    def test_fill_profile_nans(self):
        prof = np.full((4, 2, 3), 1.0)
        prof[2, 1, 2] = np.nan
        filled = fill_profile_nans(prof)
        assert np.isfinite(filled).all()
        assert filled[2, 1, 2] == pytest.approx(1.0)

    def test_select_threshold_max_drop(self):
        rng = np.random.default_rng(0)
        prof = rng.lognormal(0.0, 1.0, size=(40, 8, 8))
        res = select_threshold(prof, tc=0.5, max_drop=0.2)
        cum = np.cumsum(prof, axis=-1)
        done = (cum < res.tau) | (np.arange(8) < 1)
        assert done.mean() >= 0.8 - 1e-9


class TestTrainerResilience:
    def _cfg(self, **kw):
        base = dict(
            steps=30, n_workers=4, microbatches=4, lr=1e-3, seed=0,
            tc=0.5, telemetry_window=16, log_every=0,
        )
        base.update(kw)
        return TrainConfig(**base)

    def test_no_fault_parity_controller_is_noop(self):
        """Controller on + no faults == the no-drop baseline, bit for bit."""
        lat = make_scenario("none", seed=0)
        off = train(TINY, DATA, self._cfg(latency=lat, drop=DropConfig(enabled=False)))
        on = train(TINY, DATA, self._cfg(
            latency=lat, drop=DropConfig(enabled=True, tau=float("inf")),
            online_tau=True,
            controller=ControllerConfig(warmup_steps=8, check_every=4),
        ))
        assert on.losses == off.losses
        assert on.metrics["tau_changes"] == 0
        assert not np.isfinite(on.tau)
        assert float(np.mean(on.drop_fractions)) == 0.0

    def test_midrun_slow_rank_online_adapts_and_cuts_iter_time(self):
        """A rank going 4x slower mid-run: the online controller applies a
        finite tau and post-onset iteration time drops measurably below
        the unadapted (tau = inf) run on the identical latency stream."""
        onset = 10
        lat = FaultyLatencyModel(
            base=MILD, faults=(BadNode(rank=1, factor=4.0, start=onset),)
        )
        kw = dict(latency=lat, steps=40)
        off = train(TINY, DATA, self._cfg(drop=DropConfig(enabled=False), **kw))
        on = train(TINY, DATA, self._cfg(
            drop=DropConfig(enabled=True, tau=float("inf")), online_tau=True,
            controller=ControllerConfig(warmup_steps=8, check_every=4), **kw,
        ))
        assert on.metrics["tau_changes"] >= 1
        applied_at = on.tau_trajectory[1][0]
        post_on = float(np.mean(on.sim_times[applied_at:]))
        post_off = float(np.mean(off.sim_times[applied_at:]))
        assert post_on < 0.8 * post_off, (post_on, post_off)
        # and the drop stays bounded: only the slow rank's tail is cut
        assert float(np.mean(on.drop_fractions)) < 0.3

    def test_pareto_ramp_online_beats_stale_static(self):
        """The acceptance shape: under a heavy tail plus a mid-run base
        ramp the one-shot calibration goes stale; online goodput must be
        strictly higher (BENCH_train.json commits the full record)."""
        lat = make_scenario("pareto", seed=0, onset=25)
        kw = dict(latency=lat, steps=60)
        static = train(TINY, DATA, self._cfg(
            drop=DropConfig(enabled=True, tau=float("inf")),
            auto_threshold=True, calibration_steps=12, **kw,
        ))
        online = train(TINY, DATA, self._cfg(
            drop=DropConfig(enabled=True, tau=float("inf")), online_tau=True,
            controller=ControllerConfig(warmup_steps=8, check_every=4), **kw,
        ))

        def goodput(r):
            good = np.sum(1.0 - np.asarray(r.drop_fractions))
            return float(good / np.sum(r.sim_times))

        assert online.metrics["tau_changes"] >= 1
        assert goodput(online) > goodput(static), (
            goodput(online), goodput(static),
        )

    def test_checkpoint_restore_parity(self, tmp_path):
        """Interrupting at the midpoint and resuming reproduces the
        uninterrupted run exactly: losses, tau trajectory, drop rates —
        the adapted tau and the telemetry window ride the checkpoint."""
        ckpt = str(tmp_path / "ckpt")
        lat = make_scenario("pareto", seed=0, onset=10)
        kw = dict(
            latency=lat, steps=40,
            drop=DropConfig(enabled=True, tau=float("inf")), online_tau=True,
            controller=ControllerConfig(warmup_steps=8, check_every=4),
        )
        part = train(TINY, DATA, self._cfg(
            steps=20, ckpt_dir=ckpt, ckpt_every=20, **{k: v for k, v in kw.items() if k != "steps"},
        ))
        resumed = train(TINY, DATA, self._cfg(resume_from=ckpt, **kw))
        full = train(TINY, DATA, self._cfg(**kw))

        assert part.losses == full.losses[:20]
        assert resumed.losses == full.losses[20:]
        assert resumed.drop_fractions == full.drop_fractions[20:]
        assert resumed.tau == pytest.approx(full.tau)
        assert resumed.tau_trajectory == full.tau_trajectory

    def test_result_exposes_drop_and_tau_series(self):
        lat = make_scenario("pareto", seed=0, onset=10)
        r = train(TINY, DATA, self._cfg(
            latency=lat, steps=30,
            drop=DropConfig(enabled=True, tau=float("inf")), online_tau=True,
            controller=ControllerConfig(warmup_steps=8, check_every=4),
        ))
        assert r.drop_rates == r.drop_fractions
        taus = r.tau_series()
        assert taus.shape == (30,)
        assert not np.isfinite(taus[0])
        if r.metrics["tau_changes"]:
            step0 = r.tau_trajectory[1][0]
            assert np.isfinite(taus[step0:]).all()
        assert r.telemetry is not None and r.telemetry["steps"] == 30
