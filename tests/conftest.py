"""Shared test configuration.

Two lanes (see pytest.ini):

* tier-1 (default): ``python -m pytest -x -q`` — fast correctness gate,
  excludes tests marked ``slow``.
* full: ``python -m pytest -q -m "slow or not slow"`` — everything,
  including per-architecture sweeps and end-to-end serving/training.

``src`` is put on sys.path here so a bare ``pytest`` works without the
``PYTHONPATH=src`` prefix.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
