"""The committed training-resilience record (``BENCH_train.json``)
parses and carries every scenario x policy cell — like the serving
record, the training benchmark trajectory is a contract.

CI regenerates the record in the full lane (``benchmarks.train_tail
--json``); this tier-1 check pins the committed copy so a PR can't
silently drop a scenario, lose the acceptance margin, or break the
parity contract (controller-on == no-drop when there is no tail).
"""
import json
import math
import os

import pytest

BENCH = os.path.join(os.path.dirname(__file__), os.pardir, "BENCH_train.json")

SCENARIOS = {"none", "pareto", "lognormal", "badnode", "stall"}
POLICIES = {"off", "static", "online"}


@pytest.fixture(scope="module")
def record():
    assert os.path.exists(BENCH), "BENCH_train.json missing at the repo root"
    with open(BENCH) as f:
        return json.load(f)


class TestBenchTrainRecord:
    def test_full_sweep_present(self, record):
        cells = {(r["scenario"], r["policy"]) for r in record["rows"]}
        assert cells == {(s, p) for s in SCENARIOS for p in POLICIES}, cells

    def test_rows_schema(self, record):
        for r in record["rows"]:
            assert math.isfinite(r["throughput_mb_s"]) and r["throughput_mb_s"] > 0, r
            assert 0.0 <= r["drop_rate"] <= 1.0, r
            assert math.isfinite(r["final_loss"]), r
            assert math.isfinite(r["mean_iter_s"]) and r["mean_iter_s"] > 0, r
            traj = r["tau_trajectory"]
            assert traj and traj[0][1] is None  # every run starts at tau=inf
            steps = [s for s, _ in traj]
            assert steps == sorted(steps)
            assert r["tau_changes"] == len(traj) - 1
            last = traj[-1][1]
            if r["tau_final"] is None:
                assert last is None
            else:
                assert last == pytest.approx(r["tau_final"], abs=1e-3)

    def test_off_policy_never_drops(self, record):
        for r in record["rows"]:
            if r["policy"] == "off":
                assert r["drop_rate"] == 0.0 and r["tau_final"] is None, r

    def test_acceptance_online_strictly_best(self, record):
        """The PR's acceptance criterion: under the seeded pareto
        straggler scenario online-tau beats BOTH tau=inf and the static
        one-shot calibration, and the measured effective speedup sits in
        the theory (eq. 11) prediction band."""
        acc = record["acceptance"]
        assert acc["scenario"] == "pareto"
        assert acc["strictly_better"] is True
        assert acc["online_vs_off"] > 1.0
        assert acc["online_vs_static"] > 1.0
        th = acc["theory"]
        assert th["within_band"] is True
        assert abs(th["ratio"] - 1.0) <= th["band"]
        assert math.isfinite(th["measured_speedup"]) and th["measured_speedup"] > 1.0

    def test_parity_no_faults_controller_noop(self, record):
        par = record["parity"]
        assert par["scenario"] == "none"
        assert par["losses_identical"] is True
        assert par["online_tau_changes"] == 0
        assert par["online_mean_drop"] == 0.0

    def test_online_adapts_on_nonstationary_scenarios(self, record):
        """The pareto ramp must show the controller actually re-adapting
        (>= 2 tau changes: one initial application, one post-ramp)."""
        row = next(r for r in record["rows"]
                   if r["scenario"] == "pareto" and r["policy"] == "online")
        assert row["tau_changes"] >= 2, row["tau_trajectory"]

    def test_config_pins_the_sweep(self, record):
        cfg = record["config"]
        assert set(cfg["scenarios"]) == SCENARIOS
        assert set(cfg["policies"]) == POLICIES
        assert cfg["steps"] >= 100 and cfg["n_workers"] >= 8
