"""Pallas kernel validation: interpret-mode sweeps vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # full-lane only; tier-1 covers this path via faster tests


def rand(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kv,s,d", [
        (1, 4, 4, 128, 64),   # MHA
        (2, 4, 2, 256, 64),   # GQA
        (1, 8, 1, 256, 32),   # MQA
        (2, 2, 2, 384, 128),  # non-pow2 seq multiple of block
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, b, h, kv, s, d, dtype):
        q = rand((b, h, s, d), dtype, 0)
        k = rand((b, kv, s, d), dtype, 1)
        v = rand((b, kv, s, d), dtype, 2)
        out = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
        )

    @pytest.mark.parametrize("window", [64, 128])
    def test_sliding_window(self, window):
        q = rand((1, 4, 256, 64), jnp.float32, 3)
        k = rand((1, 2, 256, 64), jnp.float32, 4)
        v = rand((1, 2, 256, 64), jnp.float32, 5)
        out = ops.flash_attention(q, k, v, causal=True, window=window, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    def test_non_causal(self):
        q = rand((1, 2, 128, 64), jnp.float32, 6)
        k = rand((1, 2, 128, 64), jnp.float32, 7)
        v = rand((1, 2, 128, 64), jnp.float32, 8)
        out = ops.flash_attention(q, k, v, causal=False, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    def test_block_size_invariance(self):
        q = rand((1, 2, 256, 64), jnp.float32, 9)
        k = rand((1, 2, 256, 64), jnp.float32, 10)
        v = rand((1, 2, 256, 64), jnp.float32, 11)
        o1 = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
        o2 = ops.flash_attention(q, k, v, block_q=128, block_k=256, interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


class TestFlashAttentionSegments:
    """Segment-id masking — the packed-serving mask term (one flattened
    sequence holding several requests; queries must stay inside their own
    request's rows)."""

    @staticmethod
    def contiguous_segments(b, s, boundaries, seed=0):
        """(B, S) int32 segment ids, contiguous runs split at `boundaries`."""
        seg = np.zeros((b, s), np.int32)
        for bnd in boundaries:
            seg[:, bnd:] += 1
        return jnp.asarray(seg)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_segment_mask_matches_ref(self, dtype):
        b, h, kv, s, d = 2, 4, 2, 256, 64
        q = rand((b, h, s, d), dtype, 0)
        k = rand((b, kv, s, d), dtype, 1)
        v = rand((b, kv, s, d), dtype, 2)
        seg = self.contiguous_segments(b, s, [96, 160])
        out = ops.flash_attention(
            q, k, v, causal=True, interpret=True,
            q_segment_ids=seg, kv_segment_ids=seg,
        )
        expect = ref.flash_attention_ref(
            q, k, v, causal=True, q_segment_ids=seg, kv_segment_ids=seg
        )
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
        )

    def test_segment_mask_with_window(self):
        q = rand((1, 4, 256, 64), jnp.float32, 3)
        k = rand((1, 2, 256, 64), jnp.float32, 4)
        v = rand((1, 2, 256, 64), jnp.float32, 5)
        seg = self.contiguous_segments(1, 256, [128])
        out = ops.flash_attention(
            q, k, v, causal=True, window=64, interpret=True,
            q_segment_ids=seg, kv_segment_ids=seg,
        )
        expect = ref.flash_attention_ref(
            q, k, v, causal=True, window=64, q_segment_ids=seg, kv_segment_ids=seg
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    def test_no_cross_segment_leak(self):
        """Adversarial: two packed slots whose *absolute* positions overlap.

        Both segments are causally visible to the second one's queries
        (they sit earlier in the flattened sequence), and segment 0's
        values are poisoned with a huge offset — any leak through the
        mask shows up at full magnitude.  Segment 1's rows must equal an
        attention computed over segment 1 alone.
        """
        s, half = 256, 128
        q = rand((1, 2, s, 64), jnp.float32, 6)
        k = rand((1, 2, s, 64), jnp.float32, 7)
        v = rand((1, 2, s, 64), jnp.float32, 8)
        v = v.at[:, :, :half].add(1e4)  # poison segment 0's values
        seg = self.contiguous_segments(1, s, [half])
        out = ops.flash_attention(
            q, k, v, causal=True, interpret=True,
            q_segment_ids=seg, kv_segment_ids=seg,
        )
        alone = ops.flash_attention(
            q[:, :, half:], k[:, :, half:], v[:, :, half:],
            causal=True, interpret=True,
        )
        np.testing.assert_allclose(
            np.asarray(out[:, :, half:]), np.asarray(alone), atol=2e-5
        )
        assert np.asarray(out[:, :, half:]).max() < 1e3, "segment-0 poison leaked"
        # and the unsegmented kernel DOES see the poison (mask is load-bearing)
        leaky = ops.flash_attention(q, k, v, causal=True, interpret=True)
        assert np.asarray(leaky[:, :, half:]).max() > 1e3


class TestPagedAttention:
    """Gather-by-block-table attention (the repro.serve.kv Paged layout):
    kernel vs jnp oracle, paged-vs-contiguous equivalence, and the
    adversarial cross-page-leak check."""

    @staticmethod
    def scenario(num_slots=3, num_blocks=4, page_size=16, kvh=2, h=4, d=32,
                 seed=0, lens=(50, 17, 64)):
        """Random per-slot KV histories scattered over an interleaved page
        pool, plus one packed query token per slot at its last position."""
        rng = np.random.default_rng(seed)
        num_pages = num_slots * num_blocks
        # interleave page ownership across slots: slot s gets pages
        # s, s+num_slots, ... — physically discontiguous on purpose
        tables = np.full((num_slots, num_blocks), num_pages, np.int32)
        k_pool = rng.normal(size=(num_pages, page_size, kvh, d)).astype(np.float32)
        v_pool = rng.normal(size=(num_pages, page_size, kvh, d)).astype(np.float32)
        contig_k, contig_v = [], []
        for s, n in enumerate(lens):
            nb = -(-n // page_size)
            pages = [s + j * num_slots for j in range(nb)]
            tables[s, :nb] = pages
            contig_k.append(np.concatenate([k_pool[p] for p in pages], axis=0))
            contig_v.append(np.concatenate([v_pool[p] for p in pages], axis=0))
        q = rng.normal(size=(num_slots, h, d)).astype(np.float32)
        q_pos = np.asarray([n - 1 for n in lens], np.int32)
        q_slots = np.arange(num_slots, dtype=np.int32)
        return (jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
                jnp.asarray(tables), jnp.asarray(q_pos), jnp.asarray(q_slots),
                contig_k, contig_v)

    @pytest.mark.parametrize("window", [0, 24])
    def test_kernel_matches_ref(self, window):
        q, kp, vp, tbl, pos, slots, _, _ = self.scenario(seed=1)
        out = ops.paged_flash_attention(q, kp, vp, tbl, pos, slots,
                                        window=window, interpret=True)
        expect = ref.paged_attention_ref(q, kp, vp, tbl, pos, slots, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    def test_matches_contiguous_oracle(self):
        """Each slot's paged output must equal dense attention over that
        slot's logically-contiguous KV alone."""
        q, kp, vp, tbl, pos, slots, ck, cv = self.scenario(seed=2)
        out = np.asarray(
            ops.paged_flash_attention(q, kp, vp, tbl, pos, slots, interpret=True)
        )
        for s in range(q.shape[0]):
            n = int(pos[s]) + 1
            dense = ref.flash_attention_ref(
                jnp.asarray(q[s][None, :, None]),  # (1, H, 1, D)
                jnp.asarray(ck[s][None, :n]).transpose(0, 2, 1, 3),
                jnp.asarray(cv[s][None, :n]).transpose(0, 2, 1, 3),
                causal=True,
            )
            np.testing.assert_allclose(
                out[s], np.asarray(dense)[0, :, 0], atol=2e-5
            )

    def test_padding_query_is_zero(self):
        q, kp, vp, tbl, pos, slots, _, _ = self.scenario(seed=3)
        slots = slots.at[1].set(-1)
        out = np.asarray(
            ops.paged_flash_attention(q, kp, vp, tbl, pos, slots, interpret=True)
        )
        np.testing.assert_array_equal(out[1], np.zeros_like(out[1]))

    def test_no_cross_page_leak(self):
        """Adversarial: poison every page the query's slot does NOT own
        with a huge value.  The block-table gather must make other slots'
        pages structurally unreachable — any leak shows at full magnitude."""
        q, kp, vp, tbl, pos, slots, ck, cv = self.scenario(seed=4)
        own = set(int(p) for p in np.asarray(tbl[0]) if p < kp.shape[0])
        poison = np.asarray([p for p in range(kp.shape[0]) if p not in own])
        vp = vp.at[poison].add(1e4)
        out = np.asarray(
            ops.paged_flash_attention(q, kp, vp, tbl, pos, slots, interpret=True)
        )
        n = int(pos[0]) + 1
        alone = ref.flash_attention_ref(
            jnp.asarray(q[0][None, :, None]),
            jnp.asarray(ck[0][None, :n]).transpose(0, 2, 1, 3),
            jnp.asarray(cv[0][None, :n]).transpose(0, 2, 1, 3),
            causal=True,
        )
        np.testing.assert_allclose(out[0], np.asarray(alone)[0, :, 0], atol=2e-5)
        assert np.abs(out[0]).max() < 1e3, "foreign page values leaked"
        # ...and a table pointing AT the poisoned pages does see them
        # (the isolation comes from the table, not luck)
        tbl_bad = tbl.at[0].set(tbl[1])
        leaky = np.asarray(
            ops.paged_flash_attention(q, kp, vp, tbl_bad, pos, slots, interpret=True)
        )
        assert np.abs(leaky[0]).max() > 1e3

    @staticmethod
    def packed_scenario(page_size=16, kvh=2, h=4, d=16, seed=0,
                       lens=(20, 9, 16)):
        """Mixed-phase packed queries over one pool: slot 0 decodes (its
        last position), slot 1 verifies (a 4-token tail), slot 2 prefills
        (every position) — the three shapes the engine routes through the
        one fused path."""
        rng = np.random.default_rng(seed)
        num_slots = len(lens)
        nb = max(-(-n // page_size) for n in lens)
        num_pages = num_slots * nb
        tables = np.full((num_slots, nb), num_pages, np.int32)
        k_pool = rng.normal(size=(num_pages, page_size, kvh, d)).astype(np.float32)
        v_pool = rng.normal(size=(num_pages, page_size, kvh, d)).astype(np.float32)
        for s, n in enumerate(lens):
            for j in range(-(-n // page_size)):
                tables[s, j] = s + j * num_slots  # interleaved ownership
        spans = [range(lens[0] - 1, lens[0]),          # decode
                 range(max(lens[1] - 4, 0), lens[1]),  # verify tail
                 range(lens[2])]                       # packed prefill
        q_pos = np.asarray([p for sp in spans for p in sp], np.int32)
        q_slots = np.asarray(
            [s for s, sp in enumerate(spans) for _ in sp], np.int32)
        q = rng.normal(size=(len(q_pos), h, d)).astype(np.float32)
        return (jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
                jnp.asarray(tables), jnp.asarray(q_pos), jnp.asarray(q_slots))

    @staticmethod
    def quantize_pool(pool):
        """Per-(token-row, kv-head) symmetric int8, the model's scheme."""
        pool = np.asarray(pool)
        amax = np.abs(pool).max(axis=-1)
        scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
        codes = np.clip(np.round(pool / scale[..., None]), -127, 127)
        return jnp.asarray(codes.astype(np.int8)), jnp.asarray(scale)

    @pytest.mark.parametrize("g", [1, 2, 4])
    @pytest.mark.parametrize("page_size", [4, 16])
    @pytest.mark.parametrize("window", [0, 7])
    def test_fused_matrix(self, g, page_size, window):
        """decode/verify/packed-prefill through the fused path across GQA
        group sizes, page sizes, and sliding windows — both the XLA
        lowering (the off-TPU dispatch) and the interpreted kernel must
        match the jnp oracle."""
        kvh = 2
        q, kp, vp, tbl, pos, slots = self.packed_scenario(
            page_size=page_size, kvh=kvh, h=g * kvh, seed=g + page_size)
        expect = np.asarray(ref.paged_attention_ref(
            q, kp, vp, tbl, pos, slots, window=window))
        xla = np.asarray(ops.paged_flash_attention(
            q, kp, vp, tbl, pos, slots, window=window))
        kern = np.asarray(ops.paged_flash_attention(
            q, kp, vp, tbl, pos, slots, window=window, interpret=True))
        np.testing.assert_allclose(xla, expect, atol=2e-5)
        np.testing.assert_allclose(kern, expect, atol=2e-5)

    def test_int8_matches_dequantized_ref(self):
        """int8 pools + scales through both fused paths == the float ref
        over the dequantized pools (the quantization is the only error
        source; the attention math must be bit-for-bit the same)."""
        q, kp, vp, tbl, pos, slots = self.packed_scenario(seed=11)
        kq, ks = self.quantize_pool(kp)
        vq, vs = self.quantize_pool(vp)
        deq_k = jnp.asarray(np.asarray(kq, np.float32) * np.asarray(ks)[..., None])
        deq_v = jnp.asarray(np.asarray(vq, np.float32) * np.asarray(vs)[..., None])
        expect = np.asarray(ref.paged_attention_ref(
            q, deq_k, deq_v, tbl, pos, slots))
        for interp in (None, True):
            out = np.asarray(ops.paged_flash_attention(
                q, kq, vq, tbl, pos, slots, k_scale=ks, v_scale=vs,
                interpret=interp))
            np.testing.assert_allclose(out, expect, atol=2e-5)

    def test_int8_quantization_error_bounded(self):
        """End-to-end int8 error against the unquantized oracle stays
        within the per-row quantization budget (~amax/127 per element)."""
        q, kp, vp, tbl, pos, slots = self.packed_scenario(seed=12)
        kq, ks = self.quantize_pool(kp)
        vq, vs = self.quantize_pool(vp)
        exact = np.asarray(ref.paged_attention_ref(q, kp, vp, tbl, pos, slots))
        out = np.asarray(ops.paged_flash_attention(
            q, kq, vq, tbl, pos, slots, k_scale=ks, v_scale=vs))
        # v rows are convex-combined, so output error is bounded by the
        # worst per-element v quantization error plus the softmax shift
        # from the k error; normal(0,1) rows quantize at ~3sigma/127
        np.testing.assert_allclose(out, exact, atol=0.1)
        assert np.abs(out - exact).max() > 0  # int8 is not bit-identical

    def test_int8_no_cross_page_leak(self):
        """Poison foreign pages in the int8 pools (max code, huge scale):
        slot 0's output must be identical to the unpoisoned run."""
        q, kp, vp, tbl, pos, slots = self.packed_scenario(seed=13)
        kq, ks = self.quantize_pool(kp)
        vq, vs = self.quantize_pool(vp)
        clean = np.asarray(ops.paged_flash_attention(
            q, kq, vq, tbl, pos, slots, k_scale=ks, v_scale=vs,
            interpret=True))
        own = set(int(p) for p in np.asarray(tbl[0]) if p < kq.shape[0])
        poison = np.asarray([p for p in range(kq.shape[0]) if p not in own])
        vq = vq.at[poison].set(127)
        vs = vs.at[poison].set(1e4)
        out = np.asarray(ops.paged_flash_attention(
            q, kq, vq, tbl, pos, slots, k_scale=ks, v_scale=vs,
            interpret=True))
        sel = np.asarray(slots) == 0
        np.testing.assert_array_equal(out[sel], clean[sel])
        assert np.abs(out[sel]).max() < 1e3, "foreign int8 pages leaked"


class TestRmsnorm:
    @pytest.mark.parametrize("shape", [(4, 128), (3, 17, 256), (1, 1, 1024), (513, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, shape, dtype):
        x = rand(shape, dtype, 0)
        s = rand((shape[-1],), jnp.float32, 1)
        out = ops.rmsnorm(x, s, interpret=True)
        expect = ref.rmsnorm_ref(x, s)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-6
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
        )


class TestMaskedAccum:
    @pytest.mark.parametrize("n", [128, 1000, 65536 + 3])
    @pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, n, gdtype):
        acc = rand((n,), jnp.float32, 0)
        g = rand((n,), gdtype, 1)
        for keep in (0.0, 1.0):
            out = ops.masked_accum(acc, g, jnp.float32(keep), scale=0.125, interpret=True)
            expect = ref.masked_accum_ref(acc, g, jnp.float32(keep), 0.125)
            np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-6)

    def test_tree_variant(self):
        accs = {"a": rand((64,), jnp.float32, 2), "b": rand((32, 8), jnp.float32, 3)}
        gs = {"a": rand((64,), jnp.bfloat16, 4), "b": rand((32, 8), jnp.bfloat16, 5)}
        out = ops.masked_accum_tree(accs, gs, jnp.float32(1.0), interpret=True)
        for k in accs:
            expect = ref.masked_accum_ref(accs[k], gs[k], jnp.float32(1.0))
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(expect), atol=1e-6)

    def test_matches_dropcompute_semantics(self):
        """keep=0 must leave the accumulator untouched (Algorithm 1 line 8)."""
        acc = rand((257,), jnp.float32, 6)
        g = rand((257,), jnp.float32, 7)
        out = ops.masked_accum(acc, g, jnp.float32(0.0), interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(acc))
