"""Validate the closed-form runtime analysis (§4, appendix C.2) against
Monte-Carlo simulation — the paper's own claims, reproduced."""
import math

import numpy as np
import pytest

from repro.core import (
    LatencyModel,
    NoiseModel,
    effective_speedup,
    expected_completed_microbatches,
    expected_max_normal,
    norm_cdf,
    norm_ppf,
    optimal_tau,
    simulate,
    speedup_vs_workers,
)
from repro.core.theory import asymptotic_max_coefficient


class TestNormalHelpers:
    def test_ppf_inverts_cdf(self):
        for p in (0.01, 0.3, 0.5, 0.9, 0.999):
            assert norm_cdf(norm_ppf(p)) == pytest.approx(p, abs=1e-6)


class TestExpectedMax:
    @pytest.mark.parametrize("n", [2, 16, 64, 256])
    def test_bailey_vs_monte_carlo(self, n):
        """eq. (4): E[max of N normals] within ~1.5% of Monte Carlo."""
        mu, sig = 1.0, 0.2
        mc = np.random.default_rng(0).normal(mu, sig, (50000, n)).max(axis=1).mean()
        th = expected_max_normal(mu, sig, n)
        assert th == pytest.approx(mc, rel=0.015)

    def test_sqrt_log_n_asymptotics(self):
        """E[T] = Theta(sqrt(log N)) — §4.2."""
        mu, sig = 0.0, 1.0
        ratios = [
            expected_max_normal(mu, sig, n) / asymptotic_max_coefficient(n)
            for n in (10**2, 10**4, 10**6)
        ]
        # ratio approaches 1 from below as N grows
        assert ratios[0] < ratios[1] < ratios[2] < 1.05
        assert ratios[2] > 0.9


class TestCompletedMicrobatches:
    def test_eq5_vs_monte_carlo(self):
        """eq. (5): E[M~] within 2% of simulation for normal latencies."""
        mu, sig, m = 0.5, 0.1, 12
        rng = np.random.default_rng(1)
        t = np.maximum(rng.normal(mu, sig, (20000, m)), 0.0)
        for tau in (4.0, 5.0, 6.0, 7.0):
            mc = (np.cumsum(t, axis=1) < tau).sum(axis=1).mean()
            th = expected_completed_microbatches(tau, mu, sig, m)
            assert th == pytest.approx(mc, rel=0.02), tau

    def test_monotone_in_tau(self):
        vals = [expected_completed_microbatches(t, 0.5, 0.1, 12) for t in np.linspace(3, 8, 20)]
        assert all(b >= a for a, b in zip(vals, vals[1:]))

    def test_saturates_at_m(self):
        assert expected_completed_microbatches(1e9, 0.5, 0.1, 12) == pytest.approx(12)


class TestEffectiveSpeedup:
    def test_large_tau_is_one(self):
        """tau >= T: no drops, no time saved => S_eff == 1."""
        s = effective_speedup(1e9, 0.5, 0.05, 12, 64, tc=0.5)
        assert s == pytest.approx(1.0, rel=1e-3)

    def test_analytic_matches_simulation_normal_noise(self):
        """fig. 3a: analytic S_eff tracks simulation under normal noise."""
        model = LatencyModel(base=0.45, noise=NoiseModel(kind="normal", mean=0.5, var=0.05))
        sim = simulate(model, iters=300, workers=64, m=12, tc=0.5, seed=3)
        mu, sig = model.mean, model.std
        for tau in (6.5, 7.0, 8.0):
            s_sim = sim.effective_speedup(tau)
            s_th = effective_speedup(tau, mu, sig, 12, 64, tc=0.5)
            assert s_th == pytest.approx(s_sim, rel=0.04), tau

    def test_speedup_grows_with_workers(self):
        """§4.4: E[S_eff(tau*)] increases with N (to infinity in the limit)."""
        out = speedup_vs_workers(0.5, 0.15, 12, [4, 16, 64, 256, 1024], tc=0.2)
        sp = [out[n]["speedup"] for n in (4, 16, 64, 256, 1024)]
        assert all(b > a for a, b in zip(sp, sp[1:]))
        assert sp[0] >= 1.0

    def test_optimal_tau_beats_endpoints(self):
        tau, s = optimal_tau(0.5, 0.15, 12, 64, tc=0.2)
        lo = effective_speedup(0.55 * 12 * 0.5, 0.5, 0.15, 12, 64, tc=0.2)
        hi = effective_speedup(1e9, 0.5, 0.15, 12, 64, tc=0.2)
        assert s >= max(lo, hi) - 1e-9


class TestSimulation:
    def test_paper_delay_statistics(self):
        """Appendix B.1: additive noise makes accumulations ~x1.5 longer on
        average and at most ~x6.5."""
        model = LatencyModel(base=0.45, noise=NoiseModel(kind="paper_lognormal"))
        rng = np.random.default_rng(0)
        t = model.sample(rng, 50, 16, 12)
        assert t.mean() / 0.45 == pytest.approx(1.5, rel=0.1)
        assert t.max() / 0.45 <= 6.6

    def test_iteration_time_is_max_over_workers(self):
        sim = simulate(LatencyModel(), 10, 8, 4, tc=0.0)
        np.testing.assert_allclose(sim.T, sim.T_n.max(axis=1))

    def test_more_workers_slower_iterations(self):
        """The straggler effect: E[T] grows with N (fig. 1 mechanism)."""
        model = LatencyModel(base=0.45, noise=NoiseModel(kind="paper_lognormal"))
        t8 = simulate(model, 100, 8, 12, seed=5).T.mean()
        t128 = simulate(model, 100, 128, 12, seed=6).T.mean()
        assert t128 > t8 * 1.1
