"""End-to-end behaviour: the paper's top-line claims on a laptop scale.

1. DropCompute preserves convergence at <=10% drop rate (Table 1a).
2. DropCompute reduces simulated wall-clock in a high-variance
   environment (fig. 5): fewer seconds to the same loss.
3. Algorithm 2 auto-selects a threshold that actually helps.
4. The host-timed engine trains a real model end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DropConfig, HostTimedEngine, LatencyModel, NoiseModel, make_grad_fn
from repro.data import DataConfig
from repro.models import ModelConfig
from repro.models.model import init_params, loss_fn
from repro.optim import adamw, apply_updates
from repro.train import TrainConfig, train

pytestmark = pytest.mark.slow  # end-to-end training loops; full lane only

TINY = ModelConfig(
    name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=251, dtype="float32", remat=False,
)
DATA = DataConfig(vocab_size=251, seq_len=64, batch_size=16, strategy="pack", seed=0)
DELAY = LatencyModel(base=0.45, noise=NoiseModel(kind="paper_lognormal"))


def run(drop_enabled, tau=np.inf, steps=25, auto=False, normalize="computed"):
    tcfg = TrainConfig(
        steps=steps, n_workers=4, microbatches=4, lr=1e-3,
        drop=DropConfig(enabled=drop_enabled, tau=tau, normalize=normalize),
        latency=DELAY, tc=0.5, auto_threshold=auto, calibration_steps=8, seed=0,
    )
    return train(TINY, DATA, tcfg)


class TestConvergence:
    def test_loss_decreases(self):
        r = run(False)
        assert r.losses[-1] < r.losses[0] - 0.5

    def test_drop_rate_10pct_matches_baseline(self):
        """Table 1a: ~10% drops change the final loss negligibly."""
        base = run(False, steps=40)
        dropped = run(True, tau=2.9, steps=40)
        assert 0.02 < np.mean(dropped.drop_fractions) < 0.15
        assert abs(dropped.losses[-1] - base.losses[-1]) < 0.08

    def test_nominal_normalization_also_converges(self):
        r = run(True, tau=2.4, steps=30, normalize="nominal")
        assert r.losses[-1] < r.losses[0] - 0.5


class TestRuntime:
    def test_dropcompute_saves_time(self):
        """fig. 5: with compute variance, DropCompute reaches the end of
        training in less simulated time."""
        base = run(False, steps=30)
        drop = run(True, tau=2.6, steps=30)
        assert drop.metrics["total_sim_time"] < 0.97 * base.metrics["total_sim_time"]

    def test_auto_threshold_selected_and_helps(self):
        r = run(True, tau=np.inf, steps=30, auto=True)
        assert np.isfinite(r.tau)
        base = run(False, steps=30)
        assert r.metrics["total_sim_time"] < base.metrics["total_sim_time"]


class TestHostTimedEndToEnd:
    def test_real_wallclock_training(self):
        """Algorithm 1 with REAL timing around jitted micro-batch grads."""
        cfg = TINY
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw(1e-3)
        state = opt.init(params)
        engine = HostTimedEngine(
            make_grad_fn(lambda p, mb: loss_fn(p, cfg, mb)),
            DropConfig(enabled=True, tau=60.0),
        )
        from repro.data import microbatches_at

        losses = []
        for step in range(8):
            mbs = microbatches_at(step, DATA, m=4)
            mbs = {k: jnp.asarray(v) for k, v in mbs.items()}
            grads, loss, stats = engine.step(params, mbs)
            upd, state = opt.update(grads, state, params)
            params = apply_updates(params, upd)
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        prof = engine.profile()
        assert prof.shape[0] == 8 and np.isfinite(prof).any()
