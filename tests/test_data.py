"""Data pipeline: determinism, packing/padding, length statistics."""
import numpy as np
import pytest

from repro.data import DataConfig, DataStream, batch_at, compute_cost_proxy, microbatches_at


class TestDeterminism:
    def test_same_step_same_batch(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, batch_size=4)
        b1 = batch_at(7, cfg)
        b2 = batch_at(7, cfg)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_different_steps_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, batch_size=4)
        assert not np.array_equal(batch_at(1, cfg)["tokens"], batch_at(2, cfg)["tokens"])

    def test_worker_shards_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, batch_size=4)
        assert not np.array_equal(
            batch_at(1, cfg, worker=0)["tokens"], batch_at(1, cfg, worker=1)["tokens"]
        )

    def test_stream_resumable(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, batch_size=2)
        s1 = DataStream(cfg)
        batches = [next(s1) for _ in range(5)]
        s2 = DataStream(cfg)
        s2.step = 3
        np.testing.assert_array_equal(next(s2)["tokens"], batches[3]["tokens"])


class TestStrategies:
    def test_pack_full_weights(self):
        cfg = DataConfig(vocab_size=100, seq_len=64, batch_size=4, strategy="pack")
        b = batch_at(0, cfg)
        assert b["weights"].sum() == 4 * 64

    def test_pad_variable_lengths(self):
        cfg = DataConfig(vocab_size=100, seq_len=256, batch_size=64, strategy="pad")
        b = batch_at(0, cfg)
        lens = b["lengths"]
        assert lens.min() >= 4 and lens.max() <= 256
        assert len(np.unique(lens)) > 5  # genuinely variable
        # weights match lengths
        np.testing.assert_array_equal(b["weights"].sum(axis=1), lens)

    def test_lognormal_lengths_skewed(self):
        """Post lengths should be right-skewed (appendix B.1 rationale)."""
        cfg = DataConfig(vocab_size=100, seq_len=2048, batch_size=512,
                         strategy="pad", len_mean=180.0, len_sigma=1.0)
        lens = batch_at(0, cfg)["lengths"].astype(float)
        assert np.mean(lens) > np.median(lens)

    def test_cost_proxy(self):
        assert compute_cost_proxy(np.array([64, 64]), 64, "pack") == 1.0
        assert compute_cost_proxy(np.array([32, 64]), 64, "pad") == pytest.approx(0.75)


class TestMicrobatches:
    def test_reshape_consistent(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, batch_size=8)
        full = batch_at(3, cfg)
        mbs = microbatches_at(3, cfg, m=4)
        assert mbs["tokens"].shape == (4, 2, 16)
        np.testing.assert_array_equal(mbs["tokens"].reshape(8, 16), full["tokens"])

    def test_divisibility_enforced(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, batch_size=8)
        with pytest.raises(AssertionError):
            microbatches_at(0, cfg, m=3)
