"""The trip-count-aware HLO cost walker (roofline foundation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze


def walked(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze(c.as_text()), c


def xla_cost(c):
    """compiled.cost_analysis() returns a dict on jax>=0.5, [dict] before."""
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


class TestWalker:
    def test_matmul_exact(self):
        a, b = jnp.ones((256, 512)), jnp.ones((512, 128))
        w, c = walked(lambda a, b: a @ b, a, b)
        assert w["flops"] == 2 * 256 * 512 * 128
        assert w["flops"] == xla_cost(c)["flops"]

    def test_scan_multiplies_body(self):
        a = jnp.ones((128, 128))

        def f(x):
            def body(c, _):
                return c @ a, None
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y

        w, c = walked(f, jnp.ones((128, 128)))
        dots = 10 * 2 * 128**3
        assert w["flops"] == pytest.approx(dots, rel=0.02)
        # XLA's own count misses the trip count
        assert xla_cost(c)["flops"] < w["flops"]
        assert w["unknown_trip_loops"] == 0

    def test_nested_scan(self):
        a = jnp.ones((64, 64))

        def f(x):
            def outer(co, _):
                def inner(ci, _):
                    return ci @ a, None
                y, _ = jax.lax.scan(inner, co, None, length=4)
                return y, None
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y

        w, _ = walked(f, jnp.ones((64, 64)))
        assert w["flops"] == pytest.approx(12 * 2 * 64**3, rel=0.05)

    def test_fori_loop(self):
        a = jnp.ones((64, 64))

        def f(x):
            return jax.lax.fori_loop(0, 7, lambda i, c: jnp.tanh(c @ a), x)

        w, _ = walked(f, jnp.ones((64, 64)))
        assert w["flops"] >= 7 * 2 * 64**3

    def test_grad_counts_both_passes(self):
        a = jnp.ones((128, 64))

        def loss(w_):
            return jnp.sum(jnp.tanh(a @ w_) ** 2)

        w, _ = walked(jax.grad(loss), jnp.ones((64, 32)))
        fwd = 2 * 128 * 64 * 32
        # fwd matmul + dL/dw matmul (a is a constant: no dL/da matmul)
        assert w["flops"] >= 1.9 * fwd

    def test_bytes_positive(self):
        w, _ = walked(lambda x: x * 2.0, jnp.ones((1000,)))
        assert w["bytes"] >= 2 * 4000
