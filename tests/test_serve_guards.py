"""Engine failure paths: typed admission errors that survive ``python -O``,
the truncation flag, and stats-reset hygiene.

The seed guards were bare ``assert``s: under ``python -O`` an over-long
request was admitted and its out-of-range scatter writes silently
dropped — wrong tokens served with no error anywhere.  These tests pin
the typed replacements, including a real ``python -O`` subprocess run.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import init_params
from repro.serve import (
    ContinuousBatcher,
    EngineStateError,
    InvalidRequestError,
    Request,
)

CFG = ModelConfig(
    name="serve-guard-t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1,
    d_ff=32, vocab_size=53, layer_pattern="G", dtype="float32", remat=False,
)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def engine(params):
    return ContinuousBatcher(params, CFG, batch_slots=1, max_len=8)


class TestSubmitValidation:
    def test_too_long_typed(self, engine):
        with pytest.raises(InvalidRequestError, match="too long"):
            engine.submit(Request(uid=0, prompt=list(range(7)), max_new_tokens=5))
        # typed error is a ValueError: pre-existing handlers keep working
        with pytest.raises(ValueError):
            engine.submit(Request(uid=0, prompt=list(range(7)), max_new_tokens=5))

    def test_empty_prompt_typed(self, engine):
        """An empty prompt used to reach ``r.prompt[-1]`` mid-step and
        die with an IndexError inside the engine loop."""
        with pytest.raises(InvalidRequestError, match="empty prompt"):
            engine.submit(Request(uid=0, prompt=[], max_new_tokens=2))

    @pytest.mark.parametrize("bad_new", [0, -3])
    def test_nonpositive_max_new_typed(self, engine, bad_new):
        with pytest.raises(InvalidRequestError, match="max_new_tokens"):
            engine.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=bad_new))

    def test_rejects_nothing_valid(self, engine):
        engine.submit(Request(uid=99, prompt=[1, 2, 3], max_new_tokens=5))
        assert engine.queue.pop().uid == 99  # valid request admitted

    @pytest.mark.parametrize("bad_kw", [{"chunk_size": 0}, {"token_budget": 0}])
    def test_constructor_knobs_typed(self, params, bad_kw):
        with pytest.raises(ValueError):
            ContinuousBatcher(params, CFG, batch_slots=1, max_len=8, **bad_kw)


class TestResetStats:
    def test_reset_while_busy_typed(self, params):
        eng = ContinuousBatcher(params, CFG, batch_slots=1, max_len=8)
        eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
        assert eng.busy
        with pytest.raises(EngineStateError, match="in flight"):
            eng.reset_stats()
        eng.run()
        eng.reset_stats()  # idle: fine

    def test_reset_clears_shared_step_counter(self, params):
        """A stale ``_shared_step`` from the last pre-reset step would
        pollute the first post-warmup StepStats row."""
        eng = ContinuousBatcher(params, CFG, batch_slots=1, max_len=8)
        eng._shared_step = 7  # as left behind by a final sharing step
        eng.reset_stats()
        assert eng._shared_step == 0
        assert eng.steps == 0 and eng.step_stats == [] and eng.finished == {}


class TestTruncation:
    def test_out_of_positions_flagged(self, params):
        """A request that slips past admission (the -O scenario this PR
        closes, or any future producer writing ``queue`` directly) must
        finish *flagged*, not silently short."""
        eng = ContinuousBatcher(params, CFG, batch_slots=1, max_len=8)
        req = Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=10)
        eng.queue.append(req)  # bypass submit, as python -O used to
        eng.run()
        assert req.uid in eng.finished
        assert len(req.output) < req.max_new_tokens
        assert req.truncated
        assert eng.stats_summary()["truncated"] == 1.0

    def test_normal_finish_not_flagged(self, params):
        eng = ContinuousBatcher(params, CFG, batch_slots=1, max_len=8)
        eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
        eng.run()
        assert not eng.finished[0].truncated
        assert eng.stats_summary()["truncated"] == 0.0


class TestPythonOptimized:
    def test_guards_survive_python_O(self):
        """The whole point of the typed errors: run the same checks in a
        ``python -O`` subprocess, where the seed's bare asserts vanished."""
        script = """
import jax
from repro.models import ModelConfig
from repro.models.model import init_params
from repro.serve import (ContinuousBatcher, EngineStateError,
                         InvalidRequestError, Request)

assert True is not False or True  # asserts are really off?  see below
cfg = ModelConfig(name="o-t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1,
                  d_ff=32, vocab_size=53, layer_pattern="G", dtype="float32",
                  remat=False)
eng = ContinuousBatcher(init_params(jax.random.PRNGKey(0), cfg), cfg,
                        batch_slots=1, max_len=8)
for bad in (
    Request(uid=0, prompt=list(range(7)), max_new_tokens=5),  # too long
    Request(uid=1, prompt=[], max_new_tokens=2),              # empty prompt
    Request(uid=2, prompt=[1], max_new_tokens=0),             # no new tokens
):
    try:
        eng.submit(bad)
    except InvalidRequestError:
        pass
    else:
        raise SystemExit(f"submit({bad.uid}) did not raise under -O")
eng.submit(Request(uid=3, prompt=[1, 2], max_new_tokens=2))
try:
    eng.reset_stats()
except EngineStateError:
    pass
else:
    raise SystemExit("reset_stats did not raise while busy under -O")
eng.run()
print("OK")
"""
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
        env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
            "PYTHONPATH", ""
        )
        proc = subprocess.run(
            [sys.executable, "-O", "-c", script],
            capture_output=True, text=True, env=env, timeout=300,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "OK" in proc.stdout
