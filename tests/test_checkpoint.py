"""Checkpoint save/restore roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def tree():
    return {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": jnp.ones((3,))},
        "opt": {"m": [jnp.zeros((2,)), jnp.full((4,), 2.0)], "count": jnp.int32(7)},
    }


class TestRoundtrip:
    def test_save_restore(self, tmp_path):
        t = tree()
        ckpt.save(str(tmp_path), t, step=42, extra={"tau": 1.5})
        restored, step = ckpt.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
        assert step == 42
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step(self, tmp_path):
        assert ckpt.latest_step(str(tmp_path)) is None
        ckpt.save(str(tmp_path), tree(), step=5)
        assert ckpt.latest_step(str(tmp_path)) == 5

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt.save(str(tmp_path), tree(), step=1)
        bad = tree()
        bad["params"]["w"] = jnp.zeros((3, 3))
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), bad)

    def test_missing_key_rejected(self, tmp_path):
        ckpt.save(str(tmp_path), {"a": jnp.ones(2)}, step=1)
        with pytest.raises(KeyError):
            ckpt.restore(str(tmp_path), {"a": jnp.ones(2), "b": jnp.ones(2)})

    def test_dtype_preserved_via_template(self, tmp_path):
        t = {"x": jnp.ones((4,), jnp.bfloat16)}
        ckpt.save(str(tmp_path), t, step=0)
        r, _ = ckpt.restore(str(tmp_path), t)
        assert r["x"].dtype == jnp.bfloat16
