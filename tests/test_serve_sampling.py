"""Per-request stochastic sampling: determinism, parity, spec exactness.

The contract under test (``serve.sampling``): output token ``i`` of a
request is sampled with ``fold_in(PRNGKey(seed), i)`` — a pure function
of (request seed, output index) — so seeded streams replay across engine
restarts and across the dense/packed/paged step programs, match a
single-request reference loop with the same keys, and stay
realization-identical when speculative decoding is on (rejection-
sampling acceptance, ``spec.accept_sampled``).  ``temperature == 0`` is
byte-identical to the pre-sampling argmax engine.

Also home of this PR's serving-path bugfix regressions: the
``DraftModelProposer`` recycled-slot/stale-history rewind and the
``StepStats.budget_overshoot`` accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import decode_step, init_decode_cache, init_params
from repro.serve import (
    ContinuousBatcher,
    DraftModelProposer,
    InvalidRequestError,
    NGramProposer,
    Proposer,
    Request,
    SamplingParams,
    SpecConfig,
    accept_greedy,
    accept_sampled,
    residual_sample,
    sample_one,
    sample_tokens,
)

CFG = ModelConfig(
    name="serve-samp-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=64, vocab_size=101, layer_pattern="LG", sliding_window=6,
    dtype="float32", remat=False,
)

PROMPT_LENS = (3, 5, 12, 4, 8)

#: the stochastic point every parity test runs at (the BENCH sampled
#: rows use the same one)
SAMPLED = SamplingParams(temperature=0.8, top_p=0.95)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def make_prompts(seed=0, lens=PROMPT_LENS):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=n).tolist() for n in lens]


def seeded(i, base=SAMPLED):
    """Per-request params: distinct seeds inside one batch."""
    return base.with_seed(100 + i)


def run_engine(params, prompts, max_new=8, sampling=seeded, **kw):
    """Run every prompt through one engine; ``sampling`` maps request
    index -> SamplingParams (None = engine default, i.e. greedy)."""
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_size", 16)
    eng = ContinuousBatcher(params, CFG, **kw)
    for i, p in enumerate(prompts):
        extra = {} if sampling is None else {"sampling": sampling(i)}
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new,
                           **extra))
    eng.run()
    return eng


def outputs(eng):
    return {u: r.output for u, r in eng.finished.items()}


def reference_stream(params, prompt, sp, max_new=8, max_len=32):
    """Single-request oracle: a one-slot ``decode_step`` loop, sampling
    each output token with ``sample_one`` and the same (seed, output
    index) keys the engine derives.  No engine code involved."""
    cache = init_decode_cache(params, CFG, 1, max_len, linear=True)
    toks = list(prompt)
    logits = None
    for t, tok in enumerate(toks):
        logits, cache = decode_step(
            params, CFG, cache, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([t], jnp.int32),
        )
    out = []
    for i in range(max_new):
        tok = sample_one(np.asarray(logits)[0, 0], sp, i)
        out.append(tok)
        logits, cache = decode_step(
            params, CFG, cache, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([len(toks) + i], jnp.int32),
        )
    return out


class JunkProposer(Proposer):
    """Deterministic junk drafts — near-total rejection, driving the
    rollback + residual-emission path on every verify step."""

    name = "junk"

    def __init__(self):
        self.calls = 0

    def propose_batch(self, asks):
        out = {}
        for slot, hist, k in asks:
            self.calls += 1
            out[slot] = [
                (hist[-1] * 7 + j * 13 + self.calls) % CFG.vocab_size
                for j in range(k)
            ]
        return out


# ---------------------------------------------------------------------------
# temperature=0 is byte-identical greedy
# ---------------------------------------------------------------------------


class TestGreedyByteIdentity:
    @pytest.fixture(scope="class")
    def oracle(self, params):
        # the pre-sampling engine: no sampling field at all
        return outputs(run_engine(params, make_prompts(), sampling=None))

    @pytest.mark.parametrize("budget", [None, 4, 16])
    @pytest.mark.parametrize("mode", ["dense", "packed", "paged"])
    def test_explicit_greedy_params_match_default(self, params, oracle,
                                                  mode, budget):
        """Explicit ``SamplingParams()`` (any seed) == the default-field
        engine across the {dense, packed, paged} x budget matrix."""
        kw = {}
        if mode == "packed":
            kw = dict(packed=True)
        elif mode == "paged":
            kw = dict(packed=True, cache="paged", page_size=4)
        eng = run_engine(
            params, make_prompts(), token_budget=budget,
            sampling=lambda i: SamplingParams(seed=17 + i), **kw,
        )
        assert outputs(eng) == oracle

    @pytest.mark.parametrize("budget", [None, 4, 16])
    def test_greedy_with_spec_unchanged(self, params, oracle, budget):
        eng = run_engine(
            params, make_prompts(), token_budget=budget,
            sampling=lambda i: SamplingParams(),
            spec=SpecConfig(NGramProposer(), k=4),
        )
        assert outputs(eng) == oracle


# ---------------------------------------------------------------------------
# seeded stochastic streams: reproducible, path-independent
# ---------------------------------------------------------------------------


class TestSampledParity:
    @pytest.fixture(scope="class")
    def oracle(self, params):
        return outputs(run_engine(params, make_prompts()))

    def test_restart_reproduces(self, params, oracle):
        """A fresh engine (new caches, new compilations) replays the
        exact streams: keys depend on nothing engine-lifetime."""
        assert outputs(run_engine(params, make_prompts())) == oracle

    @pytest.mark.parametrize("budget", [None, 4, 16])
    @pytest.mark.parametrize("mode", ["dense", "packed", "paged"])
    def test_step_path_matrix(self, params, oracle, mode, budget):
        """{dense, packed, paged} x budgets {None, 4, 16}: identical
        seeded streams — the packed per-token slot-gathered keys and the
        paged layout sample exactly what the dense oracle samples."""
        kw = {}
        if mode == "packed":
            kw = dict(packed=True)
        elif mode == "paged":
            kw = dict(packed=True, cache="paged", page_size=4)
        eng = run_engine(params, make_prompts(), token_budget=budget, **kw)
        assert outputs(eng) == oracle

    def test_matches_single_request_reference(self, params, oracle):
        """The batched engine == a no-engine decode_step loop sampling
        with the same (seed, output index) keys, per request."""
        for i, p in enumerate(make_prompts()):
            ref = reference_stream(params, p, seeded(i))
            assert ref == oracle[i], (i, ref, oracle[i])

    def test_greedy_reference_matches(self, params):
        """Same reference loop at temperature 0 == the greedy engine."""
        greedy = outputs(run_engine(params, make_prompts(), sampling=None))
        for i, p in enumerate(make_prompts()):
            ref = reference_stream(params, p, SamplingParams())
            assert ref == greedy[i]

    def test_distinct_seeds_independent(self, params):
        """Same prompt, same batch, different seeds -> different streams;
        same seed -> the same stream."""
        p = make_prompts()[2]
        eng = ContinuousBatcher(params, CFG, batch_slots=3, max_len=32,
                                chunk_size=16)
        for uid, seed in enumerate((1, 2, 1)):
            eng.submit(Request(uid=uid, prompt=list(p), max_new_tokens=8,
                               sampling=SAMPLED.with_seed(seed)))
        fin = eng.run()
        assert fin[0].output == fin[2].output  # seed 1 twice
        assert fin[0].output != fin[1].output  # seeds 1 vs 2

    def test_mixed_greedy_and_sampled_batch(self, params):
        """Greedy and stochastic requests share a batched step without
        perturbing each other: each matches its own solo reference."""
        prompts = make_prompts()
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=32,
                                chunk_size=16, token_budget=4)
        for i, p in enumerate(prompts):
            sp = SamplingParams() if i % 2 == 0 else seeded(i)
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=8,
                               sampling=sp))
        got = {u: r.output for u, r in eng.run().items()}
        for i, p in enumerate(prompts):
            sp = SamplingParams() if i % 2 == 0 else seeded(i)
            assert got[i] == reference_stream(params, p, sp), i

    def test_top_k_and_top_p_thread_through(self, params):
        """Non-trivial filtering params change the stream and still
        replay (engine vs reference, not just engine vs engine)."""
        base = SamplingParams(temperature=1.5, top_k=7, top_p=0.8)
        eng = run_engine(params, make_prompts(),
                         sampling=lambda i: base.with_seed(50 + i),
                         packed=True, cache="paged", page_size=4)
        for i, p in enumerate(make_prompts()):
            ref = reference_stream(params, p, base.with_seed(50 + i))
            assert outputs(eng)[i] == ref


# ---------------------------------------------------------------------------
# rejection-sampling speculation == non-spec sampled streams
# ---------------------------------------------------------------------------


class TestSpecSampledExactness:
    @pytest.fixture(scope="class")
    def oracle(self, params):
        return outputs(run_engine(params, make_prompts()))

    @pytest.mark.parametrize("budget", [None, 4, 16])
    @pytest.mark.parametrize("cache", ["dense", "paged"])
    def test_ngram_matrix(self, params, oracle, cache, budget):
        eng = run_engine(
            params, make_prompts(), token_budget=budget, cache=cache,
            spec=SpecConfig(NGramProposer(), k=4),
        )
        assert outputs(eng) == oracle
        if eng.kv is not None:
            assert eng.kv.used_pages == 0

    @pytest.mark.parametrize("cache", ["dense", "paged"])
    def test_junk_proposer_rollback_exact(self, params, oracle, cache):
        """~0% acceptance under sampling: every step rejects drafts and
        emits the target's own sample (the residual-coupled token) after
        rolling the junk KV back — streams still exactly match."""
        eng = run_engine(
            params, make_prompts(), cache=cache,
            spec=SpecConfig(JunkProposer(), k=3),
        )
        assert outputs(eng) == oracle
        summ = eng.stats_summary()
        assert summ["draft_tokens"] > 0
        assert summ["acceptance_rate"] < 0.5  # junk rarely matches

    def test_draft_model_proposer_sampled_exact(self, params, oracle):
        prop = DraftModelProposer(params, CFG, batch_slots=2, max_len=32)
        eng = run_engine(params, make_prompts(), packed=True, cache="paged",
                         page_size=4, spec=SpecConfig(prop, k=3))
        assert outputs(eng) == oracle


# ---------------------------------------------------------------------------
# sampler units: masking, validation, residual form
# ---------------------------------------------------------------------------


def _sample_rows(logits, *, seeds, oidx, t, tk=0, tp=1.0):
    n = logits.shape[0]
    return np.asarray(sample_tokens(
        jnp.asarray(logits),
        np.broadcast_to(np.asarray(seeds, np.uint32), (n,)),
        np.broadcast_to(np.asarray(oidx, np.int32), (n,)),
        np.broadcast_to(np.asarray(t, np.float32), (n,)),
        np.broadcast_to(np.asarray(tk, np.int32), (n,)),
        np.broadcast_to(np.asarray(tp, np.float32), (n,)),
    ))


class TestSamplerUnits:
    def setup_method(self):
        self.logits = np.asarray(
            np.random.default_rng(3).normal(size=(16, 33)), np.float32
        )

    def test_temperature_zero_is_argmax(self):
        got = _sample_rows(self.logits, seeds=9, oidx=4, t=0.0)
        np.testing.assert_array_equal(got, self.logits.argmax(-1))

    def test_top_k_one_is_argmax(self):
        got = _sample_rows(self.logits, seeds=9, oidx=4, t=1.7, tk=1)
        np.testing.assert_array_equal(got, self.logits.argmax(-1))

    def test_tiny_top_p_is_argmax(self):
        # the exclusive-cumsum form always keeps the top token
        got = _sample_rows(self.logits, seeds=9, oidx=4, t=1.7, tp=1e-9)
        np.testing.assert_array_equal(got, self.logits.argmax(-1))

    def test_top_k_support_respected(self):
        k = 5
        top = np.argsort(self.logits, axis=-1)[:, -k:]
        for idx in range(6):
            got = _sample_rows(self.logits, seeds=123, oidx=idx, t=5.0, tk=k)
            for row, tok in enumerate(got):
                assert tok in top[row]

    def test_key_depends_on_seed_and_index(self):
        a = _sample_rows(self.logits, seeds=1, oidx=0, t=1.5)
        b = _sample_rows(self.logits, seeds=2, oidx=0, t=1.5)
        c = _sample_rows(self.logits, seeds=1, oidx=1, t=1.5)
        a2 = _sample_rows(self.logits, seeds=1, oidx=0, t=1.5)
        np.testing.assert_array_equal(a, a2)
        assert not np.array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_sample_one_agrees_with_batch(self):
        sp = SamplingParams(temperature=0.9, top_k=11, top_p=0.7, seed=42)
        got = np.asarray(sample_tokens(
            jnp.asarray(self.logits),
            np.full((16,), sp.seed, np.uint32),
            np.arange(16, dtype=np.int32),
            np.full((16,), sp.temperature, np.float32),
            np.full((16,), sp.top_k, np.int32),
            np.full((16,), sp.top_p, np.float32),
        ))
        for i in range(16):
            assert sample_one(self.logits[i], sp, i) == got[i]

    def test_params_validation(self):
        for bad in (dict(temperature=-0.1), dict(temperature=float("nan")),
                    dict(top_k=-1), dict(top_p=0.0), dict(top_p=1.5),
                    dict(seed="abc")):
            with pytest.raises(ValueError):
                SamplingParams(**bad)

    def test_engine_rejects_non_params(self, params):
        eng = ContinuousBatcher(params, CFG, batch_slots=1, max_len=16)
        req = Request(uid=0, prompt=[1, 2], max_new_tokens=2)
        req.sampling = {"temperature": 1.0}  # duck-typed stand-in
        with pytest.raises(InvalidRequestError):
            eng.submit(req)

    def test_accept_sampled_prefix_and_greedy_alias(self):
        assert accept_sampled([5, 6, 7], [5, 6, 9, 0]) == (2, [5, 6, 9])
        assert accept_sampled([1], [2, 3]) == (0, [2])
        assert accept_sampled([], [4]) == (0, [4])
        assert accept_greedy([5, 6], [5, 6, 7]) == \
            accept_sampled([5, 6], [5, 6, 7])

    def test_residual_sample_marginal(self):
        """MC check of the residual distribution norm(max(p - q, 0)):
        per-token frequencies over many fixed keys match the analytic
        residual (and the q==p degenerate case falls back to p)."""
        v = 6
        logits = jnp.asarray([0.5, 1.5, -0.3, 0.9, 0.0, -1.0], jnp.float32)
        p = np.asarray(jax.nn.softmax(logits))
        q = np.zeros(v, np.float32)
        q[1] = 1.0  # one-hot draft at the mode
        resid = np.maximum(p - q, 0)
        resid /= resid.sum()
        n = 4000
        keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n, dtype=jnp.uint32))
        toks = np.asarray(jax.vmap(
            lambda k: residual_sample(logits, jnp.asarray(q), k)
        )(keys))
        freq = np.bincount(toks, minlength=v) / n
        assert freq[1] == 0.0  # the drafted token never resamples
        np.testing.assert_allclose(freq, resid, atol=0.03)
        # degenerate q == p: falls back to p itself
        toks = np.asarray(jax.vmap(
            lambda k: residual_sample(logits, jnp.asarray(p), k)
        )(keys))
        freq = np.bincount(toks, minlength=v) / n
        np.testing.assert_allclose(freq, p, atol=0.03)

    def test_coupled_acceptance_marginal_is_target(self):
        """The engine's coupling (sample x ~ p per column, accept the
        one-hot draft iff x == d) has the rejection-sampling marginal:
        emitted-token frequencies == p, and P(accept) == p(d)."""
        v = 6
        logits = np.asarray([0.2, 1.1, -0.5, 0.7, -0.2, 0.4], np.float32)
        p = np.asarray(jax.nn.softmax(jnp.asarray(logits)))
        d = 3  # drafted token
        n = 4000
        rows = np.broadcast_to(logits, (n, v))
        toks = _sample_rows(rows, seeds=np.arange(n), oidx=0, t=1.0)
        freq = np.bincount(toks, minlength=v) / n
        np.testing.assert_allclose(freq, p, atol=0.03)
        accept = float(np.mean(toks == d))
        assert accept == pytest.approx(p[d], abs=0.03)
        # rejected draws are the residual: p conditioned on != d
        rej = toks[toks != d]
        resid = p.copy()
        resid[d] = 0
        resid /= resid.sum()
        freq = np.bincount(rej, minlength=v) / len(rej)
        np.testing.assert_allclose(freq, resid, atol=0.03)


# ---------------------------------------------------------------------------
# bugfix sweep: draft-proposer slot recycling, budget overshoot
# ---------------------------------------------------------------------------


class TestDraftProposerRecycledSlot:
    def test_longer_history_in_recycled_slot_rewinds(self, params):
        """A recycled slot whose new request has a *longer* history than
        the stale cursor must re-prefill from the divergence point, not
        catch up from another request's KV.  (The old guard only reset
        on ``_pos > len(h)``, so this exact shape proposed from stale
        rows whenever ``free_slot`` was missed — e.g. a proposer reused
        across engines.)"""
        prompts = make_prompts(seed=5, lens=(6, 14))
        stale = DraftModelProposer(params, CFG, batch_slots=1, max_len=32)
        stale.propose_batch([(0, list(prompts[0]), 3)])
        # no free_slot: slot 0 now holds prompts[0]'s KV, cursor 6
        got = stale.propose_batch([(0, list(prompts[1]), 3)])
        fresh = DraftModelProposer(params, CFG, batch_slots=1, max_len=32)
        want = fresh.propose_batch([(0, list(prompts[1]), 3)])
        assert got == want

    def test_shared_prefix_rewinds_to_divergence(self, params):
        """Divergence mid-history: only the suffix past the longest
        common prefix re-prefills, and drafts still match a fresh
        proposer's."""
        base = make_prompts(seed=6, lens=(10,))[0]
        h1 = base[:8] + [7, 7]
        h2 = base[:8] + [9, 9, 9, 9]
        prop = DraftModelProposer(params, CFG, batch_slots=1, max_len=32)
        prop.propose_batch([(0, list(h1), 3)])
        got = prop.propose_batch([(0, list(h2), 3)])
        fresh = DraftModelProposer(params, CFG, batch_slots=1, max_len=32)
        want = fresh.propose_batch([(0, list(h2), 3)])
        assert got == want

    def test_engine_recycles_slot_to_longer_request(self, params):
        """The ISSUE scenario end to end: short request finishes, a
        longer request lands in the same slot.  With draft == target
        every greedy draft must be accepted — stale draft KV would show
        up here as a collapsed acceptance rate."""
        prompts = make_prompts(seed=7, lens=(3, 12))
        prop = DraftModelProposer(params, CFG, batch_slots=1, max_len=32)
        eng = ContinuousBatcher(
            params, CFG, batch_slots=1, max_len=32, chunk_size=16,
            spec=SpecConfig(prop, k=3),
        )
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=6))
        eng.run()
        summ = eng.stats_summary()
        assert summ["draft_tokens"] > 0
        assert summ["acceptance_rate"] == 1.0


class TestBudgetOvershoot:
    def test_decode_batch_plus_starvation_guard(self, params):
        """token_budget=1 with a full decode batch and a queued prefill:
        the step schedules one decode baseline per busy slot
        (unconditional) + 1 starvation-guard prefill token — overshoot =
        scheduled - 1, reported, not hidden."""
        eng = ContinuousBatcher(params, CFG, batch_slots=3, max_len=32,
                                chunk_size=16, token_budget=1)
        for i in range(2):
            eng.submit(Request(uid=i, prompt=[1 + i, 2, 3],
                               max_new_tokens=12))
        # drive both requests past prefill into decode (admission and
        # prefill both happen inside step(); budget=1 prefills serially),
        # leaving the third slot free for the incoming prompt
        while any(s.prefilling for s in eng.slots) or eng.steps == 0:
            eng.step()
        eng.submit(Request(uid=9, prompt=list(range(1, 11)),
                           max_new_tokens=4))
        eng.step()  # 2 decode baselines + 1 guarded prefill token
        st = eng.step_stats[-1]
        assert st.decode_tokens == 2
        assert st.prefill_tokens == 1  # starvation guard
        assert st.scheduled_tokens == 3
        assert st.budget_overshoot == 2
        summ = eng.stats_summary()
        assert summ["max_budget_overshoot"] >= 2.0
        assert summ["budget_overshoot_tokens"] >= 2.0

    def test_no_budget_no_overshoot(self, params):
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=32)
        eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
        eng.run()
        assert all(s.budget_overshoot == 0 for s in eng.step_stats)

    def test_within_budget_no_overshoot(self, params):
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=32,
                                chunk_size=4, token_budget=16)
        eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
        eng.submit(Request(uid=1, prompt=[4, 5], max_new_tokens=4))
        eng.run()
        assert all(s.budget_overshoot == 0 for s in eng.step_stats)
