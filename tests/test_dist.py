"""Distribution tests: sharding rules + multi-device parity (subprocess).

Multi-device tests run in a subprocess so the 8 fake host devices never
leak into the rest of the suite (smoke tests must see 1 device).

Meshes are built through ``repro.dist.mesh.make_mesh``, the jax-0.4/0.5
compat helper, so this module *executes* on jax 0.4.x (no
``jax.sharding.AxisType``) instead of skipping forever.
"""
import importlib.util
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

pytestmark = pytest.mark.skipif(
    importlib.util.find_spec("repro.dist") is None,
    reason="needs the repro.dist package",
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestShardingRules:
    def setup_method(self):
        from repro.dist.mesh import make_mesh  # 1 device mesh ok
        self.mesh = make_mesh((1, 1), ("data", "model"))

    def test_spec_paths(self):
        from repro.dist.sharding import spec_for_path
        # 1-device mesh: everything divisible -> axes kept
        assert spec_for_path("stack/groups/0/attn/wq", (4, 64, 8, 16), self.mesh) == P(
            None, ("data",), "model", None
        ) or spec_for_path("stack/groups/0/attn/wq", (4, 64, 8, 16), self.mesh) is not None

    def test_right_alignment_covers_stacked(self):
        from repro.dist.sharding import spec_for_path
        s1 = spec_for_path("tail/0/mlp/w_in", (64, 256), self.mesh)
        s2 = spec_for_path("groups/0/mlp/w_in", (4, 64, 256), self.mesh)
        # stacked variant = same spec with a leading None
        assert tuple(s2) == (None,) + tuple(s1)

    def test_nondivisible_axis_dropped(self):
        from repro.dist.sharding import _fit_spec
        from repro.dist.mesh import make_mesh
        mesh = make_mesh((1, 1), ("data", "model"))
        # dims divisible by 1 always -> axes kept; use fake sizes via spec test
        sp = _fit_spec((7,), ("model",), mesh)
        assert sp == P("model")  # size-1 axis always divides


class TestMultiDevice:
    def test_spmd_moe_matches_dense(self):
        out = run_sub("""
            import jax, jax.numpy as jnp
            from repro.dist.mesh import make_mesh
            from repro.models.config import ModelConfig
            from repro.models.moe import init_moe, apply_moe_spmd, apply_moe_dense
            mesh = make_mesh((4, 2), ("data", "model"))
            cfg = ModelConfig(name='m', d_model=32, d_ff=64, n_experts=4, top_k=2,
                              capacity_factor=8.0, dtype='float32')
            p = init_moe(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
            yd, _ = apply_moe_dense(p, x, cfg)
            with mesh:
                ys, _ = jax.jit(lambda p, x: apply_moe_spmd(p, x, cfg, mesh))(p, x)
            print("ERR", float(jnp.abs(ys - yd).max()))
        """)
        err = float(out.strip().split("ERR")[1])
        assert err < 1e-5

    def test_spmd_moe_d_psum_scheme_matches_dense(self):
        """f < d selects the d_psum expert-TP factorization (qwen3-like)."""
        out = run_sub("""
            import jax, jax.numpy as jnp
            from repro.dist.mesh import make_mesh
            from repro.models.config import ModelConfig
            from repro.models.moe import init_moe, apply_moe_spmd, apply_moe_dense
            mesh = make_mesh((4, 2), ("data", "model"))
            cfg = ModelConfig(name='m', d_model=64, d_ff=32, n_experts=4, top_k=2,
                              capacity_factor=8.0, dtype='float32')
            p = init_moe(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
            yd, _ = apply_moe_dense(p, x, cfg)
            with mesh:
                ys, _ = jax.jit(lambda p, x: apply_moe_spmd(p, x, cfg, mesh))(p, x)
            print("ERR", float(jnp.abs(ys - yd).max()))
        """)
        err = float(out.strip().split("ERR")[1])
        assert err < 1e-5

    def test_sharded_train_step_matches_single_device(self):
        """The SPMD DropCompute train step produces the same loss/params as
        the single-device trainer math on a small model."""
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np, dataclasses
            from repro.models.config import ModelConfig, InputShape
            from repro.models.model import init_params
            from repro.core.dropcompute import DropConfig
            from repro.launch import steps as S
            from repro.dist.mesh import make_mesh
            from repro.dist.sharding import param_shardings, opt_shardings

            cfg = ModelConfig(name='t', n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                              d_ff=64, vocab_size=101, dtype='float32', remat=False)
            shape = InputShape('t', 16, 8, 'train', microbatches=2)
            mesh = make_mesh((4, 2), ("data", "model"))
            params = init_params(jax.random.PRNGKey(0), cfg)
            drop = DropConfig(enabled=True, tau=1.5)
            lat = jnp.ones((4, 2), jnp.float32)  # each worker: keep 1 of 2
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 101)
            batch = {'tokens': toks, 'weights': jnp.ones((8, 16), jnp.float32)}

            opt, step = S.make_train_step(cfg, shape, drop, n_workers=4, lr=1e-2)
            o0 = opt.init(params)
            with mesh:
                p_sh = param_shardings(jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg)), mesh)
                o_sh = opt_shardings(jax.eval_shape(opt.init, params), mesh)
                f = jax.jit(step, in_shardings=(p_sh, o_sh, None, None),
                            out_shardings=(p_sh, o_sh, None))
                p1, o1, metrics = f(params, o0, batch, lat)
            # single-device reference
            opt2, step2 = S.make_train_step(cfg, shape, drop, n_workers=4, lr=1e-2)
            p2, o2, m2 = jax.jit(step2)(params, opt.init(params), batch, lat)
            d = max(float(jnp.abs(a - b).max()) for a, b in
                    zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
            print("LOSSDIFF", abs(float(metrics['loss']) - float(m2['loss'])), "PD", d,
                  "FRAC", float(metrics['completed_fraction']))
        """)
        parts = out.split()
        lossdiff = float(parts[parts.index("LOSSDIFF") + 1])
        pd = float(parts[parts.index("PD") + 1])
        frac = float(parts[parts.index("FRAC") + 1])
        assert lossdiff < 1e-4
        assert pd < 1e-4
        assert frac == pytest.approx(0.5)

    def test_dev_mesh_collective_schedule(self):
        """Gradient All-Reduce appears over the data axis on a real mesh."""
        out = run_sub("""
            import jax, jax.numpy as jnp
            from repro.models.config import ModelConfig, InputShape
            from repro.core.dropcompute import DropConfig
            from repro.launch import steps as S
            from repro.dist.mesh import make_mesh
            from repro.dist.sharding import param_shardings, opt_shardings
            from repro.models.model import init_params

            cfg = ModelConfig(name='t', n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                              d_ff=64, vocab_size=101, dtype='float32', remat=False)
            shape = InputShape('t', 16, 16, 'train', microbatches=2)
            mesh = make_mesh((8, 1), ("data", "model"))
            pa = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
            opt, step = S.make_train_step(cfg, shape, DropConfig(enabled=False), n_workers=8)
            oa = jax.eval_shape(opt.init, pa)
            sds = jax.ShapeDtypeStruct
            batch = {'tokens': sds((16, 16), jnp.int32), 'weights': sds((16, 16), jnp.float32)}
            with mesh:
                p_sh = param_shardings(pa, mesh)
                o_sh = opt_shardings(oa, mesh)
                from repro.dist.sharding import batch_spec
                from jax.sharding import NamedSharding, PartitionSpec as P
                bsh = jax.tree.map(lambda x: NamedSharding(mesh, P('data', *[None]*(len(x.shape)-1))), batch)
                lowered = jax.jit(step, in_shardings=(p_sh, o_sh, bsh, NamedSharding(mesh, P('data', None)))).lower(
                    pa, oa, batch, sds((8, 2), jnp.float32))
                c = lowered.compile()
            txt = c.as_text()
            print("HAS_AR", ("all-reduce" in txt) or ("reduce-scatter" in txt))
        """)
        assert "HAS_AR True" in out
