"""Engine parity: the three Algorithm-1 implementations agree.

Given identical latencies (same micro-batches kept), ``InGraphEngine``,
``HostTimedEngine``'s normalization math, and the SPMD step from
``launch.steps.make_train_step`` must produce the same loss and
``completed_fraction`` on a small model — this pins ``core/engine.py``
to the ``repro.dist`` SPMD path so the two can never drift apart.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dropcompute import DropConfig
from repro.core.engine import HostTimedEngine, InGraphEngine, make_grad_fn
from repro.launch import steps as S
from repro.models.config import InputShape, ModelConfig
from repro.models.model import init_params, loss_fn

M = 4          # micro-batches
MBW = 2        # rows per micro-batch
B = M * MBW    # global batch (one worker)
SEQ = 16
KEPT = 2       # latencies below keep exactly 2 of 4


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                      d_ff=64, vocab_size=101, dtype="float32", remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, SEQ), 0, 101)
    batch = {"tokens": toks, "weights": jnp.ones((B, SEQ), jnp.float32)}
    stack = {k: v.reshape(M, MBW, SEQ) for k, v in batch.items()}
    grad_fn = make_grad_fn(lambda p, mb: loss_fn(p, cfg, mb))
    return cfg, params, batch, stack, grad_fn


def _tree_maxdiff(a, b):
    return max(
        float(jnp.abs(x - y).max())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


@pytest.mark.parametrize("normalize", ["computed", "nominal"])
def test_three_engines_agree_on_loss_and_fraction(setup, normalize):
    cfg, params, batch, stack, grad_fn = setup
    # unit latencies, tau = KEPT + 0.5 -> cumsum keeps exactly KEPT of M
    lat = jnp.ones((M,), jnp.float32)
    tau = KEPT + 0.5

    ig = InGraphEngine(grad_fn, DropConfig(enabled=True, tau=tau, normalize=normalize))
    g_ig, loss_ig, st_ig = ig.step(params, stack, lat)

    # HostTimedEngine drops on wall clock; tau=0 + min_microbatches=KEPT
    # deterministically computes exactly KEPT micro-batches.
    ht = HostTimedEngine(
        grad_fn,
        DropConfig(enabled=True, tau=0.0, normalize=normalize, min_microbatches=KEPT),
    )
    g_ht, loss_ht, st_ht = ht.step(params, stack)

    drop = DropConfig(enabled=True, tau=tau, normalize=normalize)
    shape = InputShape("t", SEQ, B, "train", microbatches=M)
    _, step = S.make_train_step(cfg, shape, drop, n_workers=1, lr=1e-2)
    opt, _ = S.make_train_step(cfg, shape, drop, n_workers=1, lr=1e-2)
    _, _, metrics = jax.jit(step)(params, opt.init(params), batch, lat[None, :])

    assert float(st_ig["completed_fraction"]) == pytest.approx(KEPT / M)
    assert st_ht["completed_fraction"] == pytest.approx(KEPT / M)
    assert float(metrics["completed_fraction"]) == pytest.approx(KEPT / M)

    assert float(loss_ht) == pytest.approx(float(loss_ig), abs=1e-5)
    assert float(metrics["loss"]) == pytest.approx(float(loss_ig), abs=1e-5)

    # normalization math: identical gradients between the two engines
    assert _tree_maxdiff(g_ig, g_ht) < 1e-5


def test_no_drop_parity(setup):
    """tau=inf: all three reduce to vanilla synchronous accumulation."""
    cfg, params, batch, stack, grad_fn = setup
    lat = jnp.ones((M,), jnp.float32)

    ig = InGraphEngine(grad_fn, DropConfig(enabled=True, tau=float("inf")))
    _, loss_ig, st_ig = ig.step(params, stack, lat)

    ht = HostTimedEngine(grad_fn, DropConfig(enabled=False))
    _, loss_ht, st_ht = ht.step(params, stack)

    drop = DropConfig(enabled=False)
    shape = InputShape("t", SEQ, B, "train", microbatches=M)
    opt, step = S.make_train_step(cfg, shape, drop, n_workers=1, lr=1e-2)
    _, _, metrics = jax.jit(step)(params, opt.init(params), batch, lat[None, :])

    assert float(st_ig["completed_fraction"]) == 1.0
    assert st_ht["completed_fraction"] == 1.0
    assert float(metrics["completed_fraction"]) == 1.0
    assert float(loss_ht) == pytest.approx(float(loss_ig), abs=1e-5)
    assert float(metrics["loss"]) == pytest.approx(float(loss_ig), abs=1e-5)
