"""SSD intra-chunk Pallas kernel vs oracle + vs the model's chunked scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # full-lane only; tier-1 covers this path via faster tests


def make_inputs(bs, nc, l, h, p, n, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (bs, nc, l, h, p), jnp.float32).astype(dtype)
    dt = jax.random.uniform(ks[1], (bs, nc, l, h), jnp.float32, 0.1, 0.9)
    # cumulative log-decay: positive, increasing within a chunk
    steps = jax.random.uniform(ks[2], (bs, nc, l, h), jnp.float32, 0.01, 0.2)
    cum = jnp.cumsum(steps, axis=2)
    b = jax.random.normal(ks[3], (bs, nc, l, n), jnp.float32)
    c = jax.random.normal(ks[4], (bs, nc, l, n), jnp.float32)
    return x, dt, cum, b, c


class TestSsdChunkKernel:
    @pytest.mark.parametrize("bs,nc,l,h,p,n", [
        (1, 2, 64, 2, 32, 16),
        (2, 1, 128, 3, 64, 32),
        (1, 4, 32, 1, 16, 8),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, bs, nc, l, h, p, n, dtype):
        x, dt, cum, b, c = make_inputs(bs, nc, l, h, p, n, dtype)
        out = ops.ssd_chunk(x, dt, cum, b, c, interpret=True)
        expect = ref.ssd_chunk_ref(x, dt, cum, b, c)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol, rtol=tol
        )

    def test_matches_model_intra_term(self):
        """The kernel computes exactly the y_intra of models.ssm._ssd_chunked
        when there is a single chunk (no inter-chunk contribution)."""
        from repro.models.ssm import _ssd_chunked

        bs, l, h, p, n = 2, 32, 2, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        x = jax.random.normal(ks[0], (bs, l, h, p), jnp.float32)
        dt = jax.random.uniform(ks[1], (bs, l, h), jnp.float32, 0.1, 0.9)
        a = jax.random.uniform(ks[2], (h,), jnp.float32, 0.1, 1.0)
        b = jax.random.normal(ks[3], (bs, l, n), jnp.float32)
        c = jax.random.normal(jax.random.PRNGKey(2), (bs, l, n), jnp.float32)

        y_model = _ssd_chunked(x, dt, a, b, c, chunk=l)  # single chunk
        cum = jnp.cumsum(dt * a, axis=1)  # (B, L, H)
        y_kernel = ops.ssd_chunk(
            x[:, None], dt[:, None], cum[:, None], b[:, None], c[:, None],
            interpret=True,
        )[:, 0]
        np.testing.assert_allclose(
            np.asarray(y_kernel), np.asarray(y_model), atol=2e-4, rtol=1e-3
        )
