"""Optimizer correctness vs an independent numpy reference + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    lamb,
    lans,
    sgd,
    warmup_cosine,
    warmup_linear,
)


def np_adamw_step(w, g, m, v, t, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**t)
    vh = v / (1 - b2**t)
    w = w - lr * (mh / (np.sqrt(vh) + eps) + wd * w)
    return w, m, v


class TestAdamW:
    def test_matches_numpy_reference(self):
        rng = np.random.default_rng(0)
        w = rng.normal(size=(5, 3)).astype(np.float32)
        params = {"w": jnp.asarray(w)}
        opt = adamw(1e-3)
        state = opt.init(params)
        m = np.zeros_like(w)
        v = np.zeros_like(w)
        for t in range(1, 6):
            g = rng.normal(size=w.shape).astype(np.float32)
            upd, state = opt.update({"w": jnp.asarray(g)}, state, params)
            params = apply_updates(params, upd)
            w, m, v = np_adamw_step(w, g, m, v, t)
            np.testing.assert_allclose(np.asarray(params["w"]), w, rtol=2e-5)

    def test_bf16_state_dtype(self):
        params = {"w": jnp.ones((4,), jnp.float32)}
        opt = adamw(1e-3, state_dtype=jnp.bfloat16)
        state = opt.init(params)
        assert state["m"]["w"].dtype == jnp.bfloat16
        upd, state = opt.update({"w": jnp.ones((4,))}, state, params)
        assert state["v"]["w"].dtype == jnp.bfloat16
        assert bool(jnp.isfinite(upd["w"]).all())


def _rosenbrockish(params):
    w = params["w"]
    return jnp.sum((w - 1.0) ** 2) + 5.0 * jnp.sum((w[1:] - w[:-1] ** 2) ** 2)


@pytest.mark.parametrize("make_opt,lr", [(adamw, 3e-2), (lamb, 3e-2), (lans, 3e-2), (sgd, 1e-3)])
def test_optimizers_descend(make_opt, lr):
    params = {"w": jnp.zeros((8,), jnp.float32)}
    opt = make_opt(lr)
    state = opt.init(params)
    l0 = float(_rosenbrockish(params))
    for _ in range(200):
        g = jax.grad(_rosenbrockish)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(_rosenbrockish(params)) < 0.25 * l0


class TestClip:
    def test_global_norm(self):
        t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
        assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))

    def test_clip_rescales(self):
        t = {"a": jnp.full((4,), 10.0)}
        c = clip_by_global_norm(t, 1.0)
        assert float(global_norm(c)) == pytest.approx(1.0, rel=1e-5)

    def test_clip_noop_below_max(self):
        t = {"a": jnp.full((4,), 0.1)}
        c = clip_by_global_norm(t, 10.0)
        np.testing.assert_allclose(np.asarray(c["a"]), 0.1, rtol=1e-6)


class TestSchedules:
    def test_warmup_linear(self):
        lr = warmup_linear(1.0, 10, 100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)
        assert float(lr(55)) == pytest.approx(0.5, rel=0.01)

    def test_warmup_cosine_endpoints(self):
        lr = warmup_cosine(1.0, 10, 100, floor=0.1)
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)
