"""Property-based tests (hypothesis) on the resilience subsystem's
invariants: tau* scaling, ring-buffer bounds, and the recompile gate."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; skipping property tests")
from hypothesis import given, settings, strategies as st

from repro.core.threshold import select_threshold
from repro.train.resilience import (
    ComputeTelemetry,
    ControllerConfig,
    RingBuffer,
    TauController,
)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.floats(min_value=1.1, max_value=8.0),
)
def test_tau_star_monotone_in_latency_scale(seed, c):
    """Scaling every fed latency (and tc) by c > 1 scales tau* with it:
    Algorithm 2 is scale-equivariant, so tau* is monotone in the latency
    quantiles it is fed — a uniformly slower cluster never gets a
    *smaller* threshold."""
    rng = np.random.default_rng(seed)
    prof = rng.lognormal(0.0, 1.0, size=(20, 4, 6))
    tc = 0.5
    r1 = select_threshold(prof, tc, grid_size=64)
    r2 = select_threshold(c * prof, c * tc, grid_size=64)
    assert r2.tau > r1.tau
    # equivariance up to the grid resolution
    assert r2.tau == pytest.approx(c * r1.tau, rel=0.08)
    assert r2.speedup == pytest.approx(r1.speedup, rel=1e-6)


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=1, max_value=16),
    st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=0, max_size=64),
)
def test_ring_buffer_never_exceeds_bound(capacity, xs):
    rb = RingBuffer(capacity)
    for i, x in enumerate(xs):
        rb.push(x)
        assert len(rb) <= capacity
        assert rb.window().shape[0] == min(i + 1, capacity)
    # the window is exactly the most recent min(len, capacity) pushes
    expect = xs[-min(len(xs), capacity):] if xs else []
    np.testing.assert_allclose(rb.window(), expect)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.floats(min_value=0.0, max_value=1e6),
)
def test_recompile_gate_never_fires_below_cost(seed, cost):
    """Whatever the (heavy-tailed) window, a tau change is applied only
    when predicted gain x steps remaining exceeds the recompile cost."""
    rng = np.random.default_rng(seed)
    tel = ComputeTelemetry(4, 6, window=16)
    ctl = TauController(
        ControllerConfig(warmup_steps=4, check_every=2, recompile_cost_s=cost),
        tc=0.5,
        total_steps=50,
    )
    for s in range(50):
        tel.record(s, rng.lognormal(0.0, 1.0, size=(4, 6)), tau=ctl.tau)
        d = ctl.maybe_update(s, tel, steps_remaining=50 - s)
        if d.applied:
            assert d.gain_per_step_s * (50 - s) > cost
        elif d.reason == "not_amortized":
            assert d.gain_per_step_s * (50 - s) <= cost
