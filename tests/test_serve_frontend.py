"""Async serving front-end: parity with the synchronous driver,
lifecycle events, layered backpressure, and the TTFT accounting split.

The acceptance bar is the parity class: the exact token streams the
synchronous ``run()`` driver produces must come back through
``AsyncEngine`` streams — dense and paged — no matter how arrivals
interleave with steps.  Everything async adds (waiting room, queue
timeout, deadline drops, cancellation) must shed load *explicitly*:
every submitted request ends in exactly one of
finished/dropped/cancelled/rejected, and a paged engine ends every test
with zero referenced pages.

All asyncio plumbing goes through ``asyncio.run`` — no async test
framework needed.  Determinism note: a coroutine only yields to the
event loop at an *actual* await point, and ``AsyncEngine.submit`` has
none — so back-to-back submits run atomically with respect to the
driver task, which is what makes the waiting-room overflow tests exact
rather than racy.
"""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import init_params
from repro.serve import (
    AdmissionError,
    AsyncEngine,
    ContinuousBatcher,
    InvalidRequestError,
    Request,
    StepStats,
)

CFG = ModelConfig(
    name="serve-fe-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab_size=101, layer_pattern="LG", sliding_window=6, dtype="float32",
    remat=False,
)

PROMPT_LENS = (3, 5, 12, 4, 8, 6)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def make_prompts(seed=0, lens=PROMPT_LENS):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=n).tolist() for n in lens]


def make_engine(params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 24)
    kw.setdefault("chunk_size", 4)
    return ContinuousBatcher(params, CFG, **kw)


def sync_outputs(params, prompts, max_new=4, **kw):
    eng = make_engine(params, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
    eng.run()
    return {u: r.output for u, r in eng.finished.items()}


async def async_outputs(eng, prompts, max_new=4, **fe_kw):
    async with AsyncEngine(eng, **fe_kw) as fe:
        streams = [await fe.submit(p, max_new) for p in prompts]
        outs = await asyncio.gather(*(s.collect() for s in streams))
    assert all(s.status == "finished" for s in streams)
    return {s.uid: out for s, out in zip(streams, outs)}, streams


# ---------------------------------------------------------------------------
# Parity: async streams == synchronous driver
# ---------------------------------------------------------------------------


class TestSyncParity:
    @pytest.mark.parametrize("cache,packed", [("dense", False),
                                              ("paged", True)])
    def test_streams_token_identical(self, params, cache, packed):
        """The acceptance criterion: submitting through the async
        front-end yields byte-identical output streams to the
        synchronous run() driver, dense and paged."""
        prompts = make_prompts()
        want = sync_outputs(params, prompts)
        kw = dict(cache=cache, packed=packed)
        if cache == "paged":
            kw["page_size"] = 8
        eng = make_engine(params, **kw)
        got, _ = asyncio.run(async_outputs(eng, prompts))
        assert got == want
        if eng.kv is not None:
            assert eng.kv.tables.used_pages == 0
            eng.kv.check_invariants()

    def test_staggered_arrivals_same_streams(self, params):
        """Arrivals interleaved with steps (sleeps between submits)
        still produce the same per-request streams — per-slot KV
        isolation makes greedy outputs schedule-independent."""
        prompts = make_prompts(seed=3)
        want = sync_outputs(params, prompts)

        async def go():
            eng = make_engine(params)
            async with AsyncEngine(eng) as fe:
                streams = []
                for p in prompts:
                    streams.append(await fe.submit(p, 4))
                    await asyncio.sleep(0.01)  # let steps interleave
                await asyncio.gather(*(s.collect() for s in streams))
            return {s.uid: s.tokens for s in streams}

        assert asyncio.run(go()) == want

    def test_tokens_stream_incrementally(self, params):
        """__anext__ yields tokens one at a time, in generation order,
        matching the request's final output."""

        async def go():
            eng = make_engine(params, batch_slots=1)
            async with AsyncEngine(eng) as fe:
                stream = await fe.submit(make_prompts()[0], 6)
                seen = [tok async for tok in stream]
            assert seen == stream.request.output and len(seen) == 6
            return stream

        stream = asyncio.run(go())
        assert stream.status == "finished"


# ---------------------------------------------------------------------------
# Lifecycle events
# ---------------------------------------------------------------------------


class TestLifecycle:
    def test_event_order_and_timestamps(self, params):
        async def go():
            eng = make_engine(params)
            async with AsyncEngine(eng) as fe:
                stream = await fe.submit(make_prompts()[2], 4)
                await stream.collect()
            return stream

        stream = asyncio.run(go())
        kinds = [e.kind for e in stream.events]
        assert kinds == ["queued", "admitted", "first_token", "finished"]
        times = [e.time for e in stream.events]
        assert times == sorted(times)
        r = stream.request
        assert stream.events[0].time == r.submitted_at
        assert stream.events[1].time == r.admitted_at
        assert stream.events[2].time == r.first_token_at

    def test_truncation_surfaces_in_finish_event(self, params):
        """validate_request makes truncation unreachable from outside,
        so force it white-box: once the request is in a slot (first
        token arrived), grow max_new_tokens so the slot runs out of
        cache positions mid-request, and check the finish event flags
        the short stream."""

        async def go():
            eng = make_engine(params, batch_slots=1, max_len=8)
            async with AsyncEngine(eng) as fe:
                stream = await fe.submit(make_prompts()[1], 3)
                await stream.__anext__()  # admitted: validation is behind us
                stream.request.max_new_tokens = 10  # 5 + 10 > max_len now
                await stream.collect()
            return stream

        stream = asyncio.run(go())
        assert stream.truncated
        assert len(stream.tokens) == 4  # (max_len 8) - (prompt 5) + 1
        assert stream.events[-1] == dataclasses.replace(
            stream.events[-1], kind="finished", detail="truncated")

    def test_driver_crash_closes_streams(self, params):
        """An unexpected engine error must end every stream (detail
        'driver_error') instead of hanging clients, and stop() must
        re-raise the original exception."""

        async def go():
            eng = make_engine(params)
            fe = AsyncEngine(eng)
            await fe.start()
            stream = await fe.submit(make_prompts()[0], 4)

            def boom():
                raise RuntimeError("boom")

            eng.step = boom
            await stream.collect()  # must terminate, not hang
            assert stream.status == "dropped"
            assert stream.events[-1].detail == "driver_error"
            assert fe.in_flight == 0
            with pytest.raises(RuntimeError, match="boom"):
                await fe.stop()

        asyncio.run(go())

    def test_counters_and_summary(self, params):
        async def go():
            eng = make_engine(params)
            async with AsyncEngine(eng) as fe:
                streams = [await fe.submit(p, 3) for p in make_prompts()[:3]]
                await asyncio.gather(*(s.collect() for s in streams))
                return fe.summary()

        summ = asyncio.run(go())
        assert summ["frontend_submitted"] == 3.0
        assert summ["frontend_finished"] == 3.0
        assert summ["frontend_dropped"] == summ["frontend_cancelled"] == 0.0
        assert summ["frontend_waiting"] == summ["frontend_live"] == 0.0
        assert summ["generated_tokens"] == 9.0


# ---------------------------------------------------------------------------
# Backpressure, timeouts, deadlines, cancellation
# ---------------------------------------------------------------------------


class TestBackpressure:
    def test_waiting_room_overflow_raises(self, params):
        """Engine queue full -> waiting room fills -> AdmissionError to
        the caller.  Exact because back-to-back submits never yield to
        the driver task."""

        async def go():
            eng = make_engine(params, batch_slots=1, max_queue=1)
            async with AsyncEngine(eng, waiting_room=2) as fe:
                streams = [await fe.submit(make_prompts()[0], 2)
                           for _ in range(2)]
                with pytest.raises(AdmissionError, match="waiting room"):
                    for _ in range(8):
                        streams.append(await fe.submit(make_prompts()[0], 2))
                await asyncio.gather(*(s.collect() for s in streams))
                assert all(s.status == "finished" for s in streams)
                # room drained: submits are accepted again
                late = await fe.submit(make_prompts()[0], 2)
                await late.collect()
                assert late.status == "finished"

        asyncio.run(go())

    def test_invalid_requests_rejected_eagerly(self, params):
        """validate_request runs at submit: requests the engine can
        never serve fail in the caller, not in the driver loop."""

        async def go():
            eng = make_engine(params, batch_slots=1)
            async with AsyncEngine(eng) as fe:
                with pytest.raises(InvalidRequestError):
                    await fe.submit([], 4)  # empty prompt
                with pytest.raises(InvalidRequestError):
                    await fe.submit([1, 2, 3], 0)  # no tokens requested
                with pytest.raises(InvalidRequestError):
                    await fe.submit(list(range(64)), 4)  # > max_len
                ok = await fe.submit([1, 2, 3], 2)
                await ok.collect()
                with pytest.raises(ValueError, match="already in flight"):
                    stream = await fe.submit([1, 2, 3], 8, uid=7)
                    await fe.submit([4, 5], 2, uid=7)
                await stream.collect()

        asyncio.run(go())

    def test_queue_timeout_zero_sheds_unadmittable_load(self, params):
        """queue_timeout=0 is 'admit now or drop': with the slot and the
        engine queue both occupied, a third request is dropped at the
        driver's next turn, with the drop visible in events/counters."""

        async def go():
            eng = make_engine(params, batch_slots=1, max_queue=1)
            async with AsyncEngine(eng, queue_timeout=0.0) as fe:
                a = await fe.submit(make_prompts()[2], 8)
                b = await fe.submit(make_prompts()[0], 2)
                c = await fe.submit(make_prompts()[1], 2)
                await asyncio.gather(a.collect(), b.collect(), c.collect())
                return fe, a, b, c

        fe, a, b, c = asyncio.run(go())
        # only a fit the engine queue at the driver's first turn; b and c
        # were not admittable *right then*, so zero-timeout sheds both
        assert a.status == "finished"
        for s in (b, c):
            assert s.status == "dropped"
            assert s.events[-1].kind == "dropped"
            assert s.events[-1].detail == "queue_timeout"
            assert s.tokens == []
        assert fe.counters["dropped"] == 2

    @pytest.mark.parametrize("cache", ["dense", "paged"])
    def test_deadline_drop_reclaims_resources(self, params, cache):
        """A request whose TTFT deadline passes before its first token is
        dropped and cancelled inside the engine — slot and pages come
        back, and the engine keeps serving everyone else."""
        kw = dict(cache=cache)
        if cache == "paged":
            kw["page_size"] = 8

        async def go():
            eng = make_engine(params, batch_slots=1, **kw)
            async with AsyncEngine(eng) as fe:
                doomed = await fe.submit(make_prompts()[2], 8, deadline_s=0.0)
                live = await fe.submit(make_prompts()[0], 4)
                await asyncio.gather(doomed.collect(), live.collect())
                return eng, fe, doomed, live

        eng, fe, doomed, live = asyncio.run(go())
        assert doomed.status == "dropped"
        assert doomed.events[-1].detail == "deadline"
        assert not doomed.met_deadline
        assert doomed.request.cancelled and doomed.request.output == []
        assert live.status == "finished" and len(live.tokens) == 4
        assert live.met_deadline  # vacuous: no deadline set, token arrived
        assert eng.stats_summary()["cancelled"] == 1.0
        if eng.kv is not None:
            assert eng.kv.tables.used_pages == 0
            eng.kv.check_invariants()

    def test_stream_cancel_mid_flight(self, params):
        """stream.cancel() after tokens have streamed: the stream ends
        with status 'cancelled', the engine reclaims the slot, and a
        queued request takes it over."""

        async def go():
            eng = make_engine(params, batch_slots=1)
            async with AsyncEngine(eng) as fe:
                victim = await fe.submit(make_prompts()[0], 16)
                successor = await fe.submit(make_prompts()[1], 3)
                got = []
                async for tok in victim:
                    got.append(tok)
                    if len(got) == 2:
                        victim.cancel()
                        victim.cancel()  # idempotent
                await successor.collect()
                return fe, victim, successor, got

        fe, victim, successor, got = asyncio.run(go())
        assert victim.status == "cancelled"
        assert 2 <= len(victim.tokens) < 16  # ended early, stream closed
        assert successor.status == "finished" and len(successor.tokens) == 3
        assert fe.counters["cancelled"] == 1
        assert fe.engine.stats_summary()["cancelled"] == 1.0

    def test_stop_without_drain_sheds_in_flight(self, params):
        async def go():
            eng = make_engine(params, batch_slots=1)
            fe = AsyncEngine(eng)
            await fe.start()
            stream = await fe.submit(make_prompts()[0], 21)
            await asyncio.sleep(0.001)  # let it get under way
            await fe.stop(drain=False)
            return fe, stream

        fe, stream = asyncio.run(go())
        assert stream.status == "dropped"
        assert stream.events[-1].detail == "shutdown"
        assert fe.in_flight == 0


# ---------------------------------------------------------------------------
# met_deadline: never a TypeError, False without a first token
# ---------------------------------------------------------------------------


class TestMetDeadline:
    """``met_deadline`` compares ``ttft <= deadline_s`` — both can be
    None.  The contract: a request that never produced a first token
    (dropped, cancelled, or still queued) is ``False``, never a
    ``TypeError``, with or without a deadline set."""

    def test_cancelled_before_first_token(self, params):
        async def go():
            eng = make_engine(params, batch_slots=1)
            async with AsyncEngine(eng) as fe:
                blocker = await fe.submit(make_prompts()[2], 8)
                # queued behind the blocker: cancelled with no tokens,
                # one with a deadline and one without
                v1 = await fe.submit(make_prompts()[0], 4, deadline_s=60.0)
                v2 = await fe.submit(make_prompts()[1], 4)
                v1.cancel()
                v2.cancel()
                await asyncio.gather(
                    blocker.collect(), v1.collect(), v2.collect()
                )
                return blocker, v1, v2

        blocker, v1, v2 = asyncio.run(go())
        assert blocker.status == "finished" and blocker.met_deadline
        for v in (v1, v2):
            assert v.status == "cancelled" and v.ttft is None
            assert v.met_deadline is False  # no first token -> False

    def test_queue_timeout_drop_without_deadline(self, params):
        """The shape the old expression would have TypeError'd on:
        dropped before any token, ``deadline_s=None`` — the
        ``self.deadline_s is None`` arm short-circuits True while
        ``ttft`` is still None."""

        async def go():
            eng = make_engine(params, batch_slots=1, max_queue=1)
            async with AsyncEngine(eng, queue_timeout=0.0) as fe:
                a = await fe.submit(make_prompts()[2], 6)
                b = await fe.submit(make_prompts()[0], 2)  # shed, no deadline
                await asyncio.gather(a.collect(), b.collect())
                return a, b

        a, b = asyncio.run(go())
        assert b.status == "dropped" and b.ttft is None
        assert b.met_deadline is False
        assert a.met_deadline is True

    def test_before_first_token_is_false_not_error(self, params):
        async def go():
            eng = make_engine(params)
            async with AsyncEngine(eng) as fe:
                s = await fe.submit(make_prompts()[0], 2)
                early = s.met_deadline  # queued: ttft is None
                await s.collect()
                return early, s

        early, s = asyncio.run(go())
        assert early is False
        assert s.met_deadline is True


# ---------------------------------------------------------------------------
# sampling passes through the front-end
# ---------------------------------------------------------------------------


class TestFrontendSampling:
    def test_sampled_streams_match_sync_driver(self, params):
        """submit(sampling=...) threads SamplingParams to the engine:
        async streams == the synchronous driver's seeded streams."""
        from repro.serve import SamplingParams

        prompts = make_prompts()
        sp = SamplingParams(temperature=0.8, top_p=0.95)
        eng = make_engine(params)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=4,
                               sampling=sp.with_seed(i)))
        eng.run()
        want = {u: r.output for u, r in eng.finished.items()}

        async def go():
            eng2 = make_engine(params)
            async with AsyncEngine(eng2) as fe:
                streams = [
                    await fe.submit(p, 4, sampling=sp.with_seed(i))
                    for i, p in enumerate(prompts)
                ]
                outs = await asyncio.gather(*(s.collect() for s in streams))
            return {s.uid: out for s, out in zip(streams, outs)}

        assert asyncio.run(go()) == want


# ---------------------------------------------------------------------------
# Step callbacks and the step log
# ---------------------------------------------------------------------------


class TestStepCallbacks:
    def test_callback_per_step_sync_driver(self, params):
        eng = make_engine(params)
        seen = []
        eng.add_step_callback(seen.append)
        for i, p in enumerate(make_prompts()[:3]):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=3))
        eng.run()
        assert len(seen) == eng.steps
        assert all(isinstance(s, StepStats) for s in seen)
        assert [s.step for s in seen] == list(range(eng.steps))
        assert seen is not eng.step_stats and seen == eng.step_stats

    def test_frontend_step_log_mirrors_engine(self, params):
        async def go():
            eng = make_engine(params)
            async with AsyncEngine(eng) as fe:
                s = await fe.submit(make_prompts()[0], 4)
                await s.collect()
                return fe

        fe = asyncio.run(go())
        assert len(fe.step_log) == fe.engine.steps
        # queue depth at step start is recorded for queue-pressure stats
        assert all(s.queued_requests >= 0 for s in fe.step_log)


# ---------------------------------------------------------------------------
# TTFT accounting split (satellite: queue_wait + admitted_ttft == ttft)
# ---------------------------------------------------------------------------


class TestTTFTAccounting:
    def test_hand_computed_split(self, params):
        """Regression-pin the stats_summary percentiles against requests
        with hand-crafted timestamps: queue_wait = admitted - submitted,
        admitted_ttft = first_token - admitted, ttft = their sum."""
        eng = make_engine(params)
        stamps = [  # (submitted, admitted, first_token)
            (10.0, 10.5, 11.0),   # qw 0.5,  attft 0.5,  ttft 1.0
            (20.0, 20.25, 21.25),  # qw 0.25, attft 1.0,  ttft 1.25
            (30.0, 32.0, 32.5),   # qw 2.0,  attft 0.5,  ttft 2.5
        ]
        for i, (sub, adm, ftk) in enumerate(stamps):
            r = Request(uid=i, prompt=[1, 2], max_new_tokens=1, output=[5],
                        submitted_at=sub, admitted_at=adm, first_token_at=ftk,
                        finished_at=ftk)
            assert r.ttft == pytest.approx(r.queue_wait + r.admitted_ttft)
            eng.finished[i] = r
        s = eng.stats_summary()
        qw, at = [0.5, 0.25, 2.0], [0.5, 1.0, 0.5]
        assert s["mean_queue_wait"] == pytest.approx(np.mean(qw))
        assert s["p50_queue_wait"] == pytest.approx(np.quantile(qw, 0.5))
        assert s["p99_queue_wait"] == pytest.approx(np.quantile(qw, 0.99))
        assert s["mean_admitted_ttft"] == pytest.approx(np.mean(at))
        assert s["p50_admitted_ttft"] == pytest.approx(np.quantile(at, 0.5))
        assert s["p99_admitted_ttft"] == pytest.approx(np.quantile(at, 0.99))
        assert s["mean_ttft"] == pytest.approx(
            s["mean_queue_wait"] + s["mean_admitted_ttft"])
        assert s["p50_ttft"] == pytest.approx(np.quantile([1.0, 1.25, 2.5], .5))

    def test_ttft_measured_from_frontend_submit(self, params):
        """A request held in the front-end waiting room accrues TTFT from
        submit(): queue_wait covers the waiting room + engine queue, and
        the identity ttft = queue_wait + admitted_ttft holds on real
        (wall-clock) runs too."""

        async def go():
            eng = make_engine(params, batch_slots=1, max_queue=1)
            async with AsyncEngine(eng, waiting_room=8) as fe:
                streams = [await fe.submit(make_prompts()[0], 4)
                           for _ in range(4)]
                await asyncio.gather(*(s.collect() for s in streams))
            return streams

        streams = asyncio.run(go())
        for s in streams:
            r = s.request
            assert r.ttft == pytest.approx(r.queue_wait + r.admitted_ttft)
        # the last request waited for three predecessors through one slot:
        # queue wait must dominate its TTFT, not be hidden by re-stamping
        last = streams[-1].request
        assert last.queue_wait > streams[0].request.queue_wait
        assert last.queue_wait >= last.admitted_ttft
