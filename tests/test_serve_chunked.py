"""Chunked-prefill serving engine: equivalence, deadline-drop, admission.

Fast tier-1 coverage for the serving path (the broader end-to-end serve
suite in test_serve.py runs in the slow lane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import (
    decode_step,
    init_decode_cache,
    init_params,
    prefill_chunk,
)
from repro.serve import AdmissionError, ContinuousBatcher, Request

CFG = ModelConfig(
    name="serve-chunk-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab_size=101, layer_pattern="LG", sliding_window=6, dtype="float32", remat=False,
)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def ref_step(params):
    """One jitted seed-style decode step, shared across tests."""
    return jax.jit(lambda c, t, pos: decode_step(params, CFG, c, t, pos))


def sequential_reference(params, ref_step, prompt, max_new, max_len):
    """Seed-style decode: one request alone, token by token (ring cache)."""
    cache = init_decode_cache(params, CFG, 1, max_len)
    out = []
    for t in range(len(prompt) + max_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        logits, cache = ref_step(cache, jnp.asarray([[cur]], jnp.int32), jnp.int32(t))
        jax.block_until_ready(logits)  # sync before reusing host buffers
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0, -1])))
    return out[:max_new]


def run_engine(params, prompts, max_new=4, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 24)
    eng = ContinuousBatcher(params, CFG, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
    done = eng.run()
    return eng, {u: r.output for u, r in done.items()}


PROMPTS_MODEL = [
    np.random.default_rng(3).integers(0, 101, size=9).tolist(),
    np.random.default_rng(4).integers(0, 101, size=5).tolist(),
]


@pytest.fixture(scope="module")
def streamed_refs(params, ref_step):
    """Token-streamed logits at each prompt's last position (computed once)."""
    refs = []
    for p in PROMPTS_MODEL:
        cache = init_decode_cache(params, CFG, 1, 24)
        for t, tok in enumerate(p):
            lg, cache = ref_step(cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(t))
            jax.block_until_ready(lg)  # sync before reusing host buffers
        refs.append(np.asarray(lg[0, 0]))
    return refs


class TestPrefillChunkModel:
    """Model-level: prefill_chunk == token-streamed decode_step."""

    @pytest.mark.parametrize("chunk", [1, 4, 16])
    def test_matches_streamed_prefill(self, params, streamed_refs, chunk):
        prompts = PROMPTS_MODEL
        b, max_len = len(prompts), 24
        refs = streamed_refs

        cache = init_decode_cache(params, CFG, b, max_len, linear=True)
        step = jax.jit(
            lambda c, toks, pos, lens: prefill_chunk(params, CFG, c, toks, pos, lens)
        )
        pos = np.zeros(b, np.int32)
        last = {}
        while any(pos[i] < len(prompts[i]) for i in range(b)):
            toks = np.zeros((b, chunk), np.int32)
            lens = np.zeros(b, np.int32)
            for i, p in enumerate(prompts):
                n = min(chunk, len(p) - pos[i])
                lens[i] = n
                toks[i, :n] = p[pos[i]: pos[i] + n]
            lg, cache = step(cache, jnp.asarray(toks), jnp.asarray(pos), jnp.asarray(lens))
            jax.block_until_ready(lg)  # sync before reusing host buffers
            for i, p in enumerate(prompts):
                if lens[i] and pos[i] + lens[i] == len(p):
                    last[i] = np.asarray(lg[i, lens[i] - 1])
                pos[i] += lens[i]
        for i, r in enumerate(refs):
            np.testing.assert_allclose(last[i], r, atol=1e-5)
            assert int(last[i].argmax()) == int(r.argmax())

    def test_encdec_rejected(self, params):
        # typed error (not a bare assert — those vanish under python -O);
        # 'R'/'M' patterns chunk-scan through this path now, but enc-dec
        # models remain decode_step-only (the non-chunkable matrix lives
        # in test_serve_packed.py)
        bad = ModelConfig(name="r", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                          d_ff=64, vocab_size=101, layer_pattern="G",
                          dtype="float32", remat=False, enc_layers=2)
        with pytest.raises(NotImplementedError, match="enc-dec"):
            prefill_chunk({}, bad, {}, jnp.zeros((1, 4), jnp.int32),
                          jnp.zeros((1,), jnp.int32), jnp.ones((1,), jnp.int32))


class TestChunkedEquivalence:
    """Engine-level: same tokens for every chunk size, including slot reuse."""

    def test_outputs_identical_across_chunk_sizes(self, params, ref_step):
        rng = np.random.default_rng(0)
        # 5 requests through 2 slots: forces slot reuse mid-session
        prompts = [rng.integers(0, 101, size=n).tolist() for n in (3, 5, 8, 4, 6)]
        outs = {}
        for chunk in (1, 4, 16):
            _, outs[chunk] = run_engine(params, prompts, chunk_size=chunk)
        assert outs[1] == outs[4] == outs[16]
        for i, p in enumerate(prompts):
            ref = sequential_reference(params, ref_step, p, 4, 24)
            assert outs[16][i] == ref, (i, outs[16][i], ref)

    def test_slot_reuse_no_stale_kv(self, params):
        """A request admitted into a used slot must not see old KV rows."""
        rng = np.random.default_rng(1)
        long_p = rng.integers(0, 101, size=12).tolist()
        short_p = rng.integers(0, 101, size=3).tolist()
        # slot is first filled to position 12+4, then reused from position 0
        _, outs = run_engine(params, [long_p, short_p], batch_slots=1, chunk_size=4)
        _, fresh = run_engine(params, [short_p], batch_slots=1, chunk_size=4)
        assert outs[1] == fresh[0]


class TestDeadlineDrop:
    """Per-step compute is bounded; decode never stalls behind a long prompt."""

    def test_budget_bounds_steps_and_decode_progresses(self, params):
        rng = np.random.default_rng(2)
        shorts = [rng.integers(0, 101, size=3).tolist() for _ in range(2)]
        long_p = rng.integers(0, 101, size=96).tolist()
        budget = 8

        eng = ContinuousBatcher(params, CFG, batch_slots=3, max_len=112,
                                chunk_size=16, token_budget=budget)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=8)
                for i, p in enumerate(shorts)]
        reqs.append(Request(uid=2, prompt=long_p, max_new_tokens=2))
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert sorted(done) == [0, 1, 2]

        # (1) the deadline bounds every step's scheduled compute
        assert max(s.scheduled_tokens for s in eng.step_stats) <= budget
        # (2) the long prompt was actually spread over many iterations
        assert done[2].ttft_steps >= len(long_p) // budget
        assert sum(s.deferred_tokens for s in eng.step_stats) > 0
        # (3) decode slots kept making progress while the long prompt was in
        # flight: both short requests emitted all their tokens and finished
        # BEFORE the long prompt produced its first token
        for u in (0, 1):
            assert done[u].finished_at < done[2].first_token_at
        # every step between the shorts' first token and their finish
        # scheduled decode work alongside the capped prefill
        s0 = done[0].first_token_step
        for st in eng.step_stats[s0 + 1: s0 + 7]:
            assert st.decode_tokens >= 1
            assert st.prefill_tokens >= 1  # starvation guard: prefill advances

        # (4) deferral never changes the generated tokens
        eng2 = ContinuousBatcher(params, CFG, batch_slots=3, max_len=112,
                                 chunk_size=16)
        for i, p in enumerate(shorts):
            eng2.submit(Request(uid=i, prompt=list(p), max_new_tokens=8))
        eng2.submit(Request(uid=2, prompt=list(long_p), max_new_tokens=2))
        done2 = eng2.run()
        assert {u: r.output for u, r in done.items()} == {
            u: r.output for u, r in done2.items()
        }


class TestAdmissionAndStats:
    def test_queue_cap(self, params):
        eng = ContinuousBatcher(params, CFG, batch_slots=1, max_len=24,
                                max_queue=2)
        eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
        eng.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=2))
        with pytest.raises(AdmissionError):
            eng.submit(Request(uid=2, prompt=[1, 2], max_new_tokens=2))
        eng.run()
        eng.submit(Request(uid=2, prompt=[1, 2], max_new_tokens=2))  # drained
        assert len(eng.run()) == 3

    def test_rejects_too_long(self, params):
        from repro.serve import InvalidRequestError

        eng = ContinuousBatcher(params, CFG, batch_slots=1, max_len=8)
        # typed (survives python -O), not the seed's bare assert
        with pytest.raises(InvalidRequestError):
            eng.submit(Request(uid=0, prompt=list(range(7)), max_new_tokens=5))

    def test_latency_stats_populated(self, params):
        rng = np.random.default_rng(4)
        eng, _ = run_engine(params, [rng.integers(0, 101, size=6).tolist()],
                            chunk_size=4)
        r = eng.finished[0]
        assert r.submitted_at <= r.first_token_at <= r.finished_at
        assert r.ttft is not None and r.ttft >= 0
        assert r.ttft_steps == 2  # 6-token prompt / chunk 4 -> 2 steps
        s = eng.stats_summary()
        assert s["finished"] == 1 and s["steps"] == eng.steps
        assert s["max_step_tokens"] >= 1 and np.isfinite(s["mean_ttft"])
