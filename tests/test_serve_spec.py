"""Speculative decoding: greedy-oracle parity, proposers, rollback.

The non-speculative engine is the oracle: speculative output streams
must be **token-identical** across every (cache layout, budget, packing)
point — acceptance keeps exactly the drafts the target model would have
emitted anyway, so correctness never depends on proposer quality.  The
adversarial `JunkProposer` (deterministic junk, ~0% acceptance) drives
the rollback path hard; hypothesis property tests pin the allocator
invariants under arbitrary fork/trim interleavings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import (
    decode_step,
    init_decode_cache,
    init_params,
    verify_step,
)
from repro.serve import (
    ContinuousBatcher,
    DraftModelProposer,
    NGramProposer,
    OutOfPages,
    PagedTables,
    Proposer,
    Request,
    SpecConfig,
    accept_greedy,
    packed_capacity,
)

CFG = ModelConfig(
    name="serve-spec-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab_size=101, layer_pattern="LG", sliding_window=6, dtype="float32", remat=False,
)

PROMPT_LENS = (3, 5, 12, 4, 8)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def make_prompts(seed=0, lens=PROMPT_LENS):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=n).tolist() for n in lens]


def run_engine(params, prompts, max_new=8, check=False, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_size", 16)
    eng = ContinuousBatcher(params, CFG, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
    if check:
        while eng.busy:
            eng.step()
            if eng.kv is not None:
                eng.kv.tables.check_invariants()
    else:
        eng.run()
    return eng


def outputs(eng):
    return {u: r.output for u, r in eng.finished.items()}


class JunkProposer(Proposer):
    """Deterministic junk drafts — near-total rejection, so every verify
    step exercises the rollback path."""

    name = "junk"

    def __init__(self):
        self.calls = 0

    def propose_batch(self, asks):
        out = {}
        for slot, hist, k in asks:
            self.calls += 1
            out[slot] = [
                (hist[-1] * 7 + j * 13 + self.calls) % CFG.vocab_size
                for j in range(k)
            ]
        return out


# ---------------------------------------------------------------------------
# Greedy-oracle parity (the acceptance matrix)
# ---------------------------------------------------------------------------


class TestSpecParity:
    @pytest.fixture(scope="class")
    def oracle(self, params):
        return run_engine(params, make_prompts())

    @pytest.mark.parametrize("budget", [None, 4, 16])
    @pytest.mark.parametrize("cache", ["dense", "paged"])
    def test_ngram_matrix(self, params, oracle, budget, cache):
        """{dense, paged} x budgets {None, 4, 16}: spec output streams are
        token-identical to the non-speculative greedy oracle."""
        eng = run_engine(
            params, make_prompts(), token_budget=budget, cache=cache,
            check=True, spec=SpecConfig(NGramProposer(), k=4),
        )
        assert outputs(eng) == outputs(oracle)
        if eng.kv is not None:
            assert eng.kv.used_pages == 0  # every page came back

    @pytest.mark.parametrize("cache", ["dense", "paged"])
    def test_junk_drafts_all_rejected_still_exact(self, params, oracle, cache):
        """~0% acceptance: every step rolls rejected KV back (trim for
        paged, position mask for dense) and the stream stays exact."""
        eng = run_engine(
            params, make_prompts(), cache=cache, token_budget=8,
            check=True, spec=SpecConfig(JunkProposer(), k=3),
        )
        assert outputs(eng) == outputs(oracle)
        s = eng.stats_summary()
        assert s["draft_tokens"] > 0
        assert s["acceptance_rate"] < 0.2  # junk is junk

    def test_packed_spec_parity(self, params, oracle):
        eng = run_engine(
            params, make_prompts(), cache="paged", packed=True,
            token_budget=8, check=True, spec=SpecConfig(NGramProposer(), k=4),
        )
        assert outputs(eng) == outputs(oracle)

    def test_spec_reduces_engine_steps(self, params, oracle):
        """Self-repeating greedy streams are n-gram territory: fewer
        engine steps per generated token than 1-token-per-step decode."""
        eng = run_engine(
            params, make_prompts(), cache="paged",
            spec=SpecConfig(NGramProposer(), k=4),
        )
        assert outputs(eng) == outputs(oracle)
        assert eng.steps < oracle.steps
        assert eng.stats_summary()["steps_per_token"] < \
            oracle.stats_summary()["steps_per_token"]

    def test_draft_model_proposer_same_model(self, params, oracle):
        """Draft == target: every draft is the target's own greedy token,
        so acceptance is total and steps collapse."""
        prop = DraftModelProposer(params, CFG, batch_slots=2, max_len=32)
        eng = run_engine(params, make_prompts(), cache="paged",
                         spec=SpecConfig(prop, k=4))
        assert outputs(eng) == outputs(oracle)
        s = eng.stats_summary()
        assert s["acceptance_rate"] == 1.0
        assert eng.steps < oracle.steps

    def test_max_new_tokens_one_perfect_proposer(self, params):
        """max_new_tokens=1 with a perfect proposer: the ask clamp leaves
        no draft room at all, so every stream is exactly one token and
        matches plain greedy decode."""
        prop = DraftModelProposer(params, CFG, batch_slots=2, max_len=32)
        eng = run_engine(params, make_prompts(), max_new=1, cache="paged",
                         check=True, spec=SpecConfig(prop, k=4))
        base = run_engine(params, make_prompts(), max_new=1)
        assert outputs(eng) == outputs(base)
        assert all(len(r.output) == 1 for r in eng.finished.values())
        assert eng.kv.used_pages == 0

    def test_emission_clamped_against_rogue_proposer(self, params):
        """A proposer that ignores its ask (drafts past max_new_tokens)
        must still produce streams of exactly max_new_tokens: the emission
        clamp is the structural guarantee, not the ask clamp."""
        max_new = 2
        prop = DraftModelProposer(params, CFG, batch_slots=2, max_len=32)
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=32,
                                chunk_size=16, cache="paged",
                                spec=SpecConfig(prop, k=4))

        def rogue():
            # bypass the ask clamp: full-k drafts even when the request
            # only has one token of budget left
            out = {}
            for i, s in enumerate(eng.slots):
                if s.free or s.prefilling:
                    continue
                r = s.req
                k = min(4, eng.max_len - s.pos - 1)
                if k > 0:
                    got = prop.propose_batch([(i, r.prompt + r.output, k)])
                    out[i] = list(got.get(i, ()))
            return out

        eng._propose = rogue
        for i, p in enumerate(make_prompts()):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
        while eng.busy:
            eng.step()
            eng.kv.tables.check_invariants()
        base = run_engine(params, make_prompts(), max_new=max_new)
        assert outputs(eng) == outputs(base)
        assert all(len(r.output) == max_new for r in eng.finished.values())
        # the clamped tail's pages were reclaimed with the slot
        assert eng.kv.used_pages == 0

    def test_budget_caps_verify_grants(self, params):
        """Draft tokens are scheduled under tau: a step's scheduled
        tokens never exceed the packed-capacity bound."""
        eng = run_engine(
            params, make_prompts(), token_budget=4, cache="paged",
            packed=True, spec=SpecConfig(NGramProposer(), k=4),
        )
        cap = packed_capacity(2, 16, 4, draft_k=4)
        assert all(s.scheduled_tokens <= cap for s in eng.step_stats)
        assert cap == packed_capacity(2, 16, 4)  # budgeted bound unchanged


# ---------------------------------------------------------------------------
# The verify path at the model level
# ---------------------------------------------------------------------------


class TestVerifyStep:
    def test_per_position_logits_match_sequential_decode(self, params):
        """One (B, 1+k) verify_step call == k+1 sequential decode_step
        calls: column j's logits are the next-token distribution after
        consuming the row through column j."""
        rng = np.random.default_rng(6)
        prompt = rng.integers(0, CFG.vocab_size, size=6).tolist()
        drafts = rng.integers(0, CFG.vocab_size, size=3).tolist()
        row = [prompt[-1]] + drafts  # [t_last, d_1..d_k] at pos 5..8

        def prefilled_cache():
            cache = init_decode_cache(params, CFG, 1, 24, linear=True)
            toks = jnp.asarray([prompt[:-1]], jnp.int32)
            _, cache = verify_step(  # prefill is the same program
                params, CFG, cache, toks,
                jnp.asarray([0], jnp.int32), jnp.asarray([5], jnp.int32))
            return cache

        vlogits, _ = verify_step(
            params, CFG, prefilled_cache(), jnp.asarray([row], jnp.int32),
            jnp.asarray([5], jnp.int32), jnp.asarray([4], jnp.int32))

        cache = prefilled_cache()
        for j, tok in enumerate(row):
            slogits, cache = decode_step(
                params, CFG, cache, jnp.asarray([[tok]], jnp.int32),
                jnp.int32(5 + j))
            np.testing.assert_allclose(
                np.asarray(vlogits[0, j]), np.asarray(slogits[0, -1]),
                atol=1e-5)


# ---------------------------------------------------------------------------
# Acceptance + proposer units
# ---------------------------------------------------------------------------


class TestAcceptGreedy:
    def test_all_accepted(self):
        a, emitted = accept_greedy([5, 6, 7], [5, 6, 7, 8])
        assert a == 3 and emitted == [5, 6, 7, 8]

    def test_first_mismatch_bonus(self):
        a, emitted = accept_greedy([5, 9, 7], [5, 6, 7, 8])
        assert a == 1 and emitted == [5, 6]

    def test_no_draft_is_plain_decode(self):
        a, emitted = accept_greedy([], [42])
        assert a == 0 and emitted == [42]

    def test_immediate_mismatch(self):
        a, emitted = accept_greedy([9], [5, 6])
        assert a == 0 and emitted == [5]


class TestNGramProposer:
    def test_proposes_continuation_of_last_match(self):
        p = NGramProposer(max_ngram=3)
        hist = [1, 2, 3, 4, 9, 9, 1, 2, 3]
        assert p.propose(hist, 2) == [4, 9]

    def test_most_recent_match_wins(self):
        p = NGramProposer(max_ngram=2)
        hist = [7, 8, 1, 7, 8, 2, 7, 8]
        assert p.propose(hist, 1) == [2]

    def test_no_match_empty(self):
        assert NGramProposer().propose([1, 2, 3, 4], 4) == []

    def test_longer_ngram_preferred(self):
        p = NGramProposer(max_ngram=3)
        # 1-gram [3] matches at index 0 (-> 5); 2-gram [2, 3] at 1 (-> 4)
        hist = [3, 5, 2, 3, 4, 2, 3]
        assert p.propose(hist, 1) == [4]

    def test_short_history(self):
        assert NGramProposer().propose([1], 4) == []
        assert NGramProposer().propose([1, 1], 4) == [1]

    def test_invalid_ngram_range(self):
        with pytest.raises(ValueError):
            NGramProposer(max_ngram=2, min_ngram=3)


class TestProposerEconomics:
    def test_no_proposer_calls_without_budget_headroom(self, params):
        """token_budget <= decode baselines leaves no room for drafts:
        the proposer (a draft model is real compute) must not run at
        all, and outputs still match the oracle."""
        counting = JunkProposer()
        eng = run_engine(params, make_prompts(), token_budget=1,
                         spec=SpecConfig(counting, k=4))
        assert counting.calls == 0
        assert all(s.draft_tokens == 0 for s in eng.step_stats)
        assert outputs(eng) == outputs(run_engine(params, make_prompts(),
                                                  token_budget=1))

    def test_ask_clamped_to_headroom(self, params):
        """With budget 4 and up to 2 decode baselines, no single ask may
        exceed the leftover headroom."""
        seen = []

        class Recording(NGramProposer):
            def propose_batch(self, asks):
                seen.extend(k for _, _, k in asks)
                return super().propose_batch(asks)

        run_engine(params, make_prompts(), token_budget=4,
                   spec=SpecConfig(Recording(), k=4))
        assert seen and max(seen) <= 3  # 4 budget - >=1 baseline

    def test_draft_proposer_geometry_validated_at_construction(self, params):
        """An undersized draft cache must fail at engine construction,
        not with an IndexError when a request lands in a high slot."""
        prop = DraftModelProposer(params, CFG, batch_slots=1, max_len=32)
        with pytest.raises(ValueError, match="cannot cover"):
            ContinuousBatcher(params, CFG, batch_slots=2, max_len=32,
                              spec=SpecConfig(prop, k=2))
        prop2 = DraftModelProposer(params, CFG, batch_slots=2, max_len=16)
        with pytest.raises(ValueError, match="cannot cover"):
            ContinuousBatcher(params, CFG, batch_slots=2, max_len=32,
                              spec=SpecConfig(prop2, k=2))


class TestSpecConfig:
    def test_k_validated(self):
        with pytest.raises(ValueError, match="k"):
            SpecConfig(NGramProposer(), k=0)

    def test_proposer_type_checked(self):
        with pytest.raises(TypeError, match="Proposer"):
            SpecConfig(proposer="ngram")

    def test_bare_proposer_wrapped(self, params):
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=24,
                                spec=NGramProposer())
        assert isinstance(eng.spec, SpecConfig) and eng.spec.k >= 1


# ---------------------------------------------------------------------------
# Rollback at the allocator level: fork_slot + trim property tests
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: f

    settings = given

    class st:  # noqa: N801
        @staticmethod
        def _none(*a, **k):
            return None

        lists = tuples = integers = _none


class TestTrim:
    def test_trim_frees_overshot_blocks(self):
        t = PagedTables(num_slots=2, num_blocks=6, num_pages=12, page_size=4)
        t.admit(0, list(range(5)), 12)
        t.prepare_write(0, 0, 5 + 8)  # 5 prompt + 8 speculative = 4 blocks
        assert len(t.tables[0]) == 4
        dropped = t.trim(0, 6)  # keep 6 tokens -> 2 blocks
        assert dropped == 2 and len(t.tables[0]) == 2
        t.check_invariants()
        # dropped blocks return to the reservation, so a re-write succeeds
        t.prepare_write(0, 6, 8)
        t.check_invariants()

    def test_trim_noop_within_kept_blocks(self):
        t = PagedTables(num_slots=1, num_blocks=4, num_pages=8, page_size=4)
        t.admit(0, list(range(5)), 3)
        t.prepare_write(0, 0, 6)
        assert t.trim(0, 6) == 0  # block holding the last kept token stays
        assert t.trim(0, 5) == 0
        t.check_invariants()

    def test_trim_after_fork_cow_isolated(self):
        """fork_slot + speculative write + trim: the parent's pages are
        untouched, the child's COW copies are freed, nothing leaks."""
        t = PagedTables(num_slots=2, num_blocks=4, num_pages=10, page_size=4)
        t.admit(0, list(range(6)), 2)
        t.prepare_write(0, 0, 6)
        parent_pages = list(t.tables[0])
        t.fork(0, 1)
        ops = t.prepare_write(1, 6, 4)  # COW block 1 + alloc block 2
        assert len(ops) == 1
        t.trim(1, 6)  # reject everything the child speculated
        t.check_invariants()
        assert t.tables[0] == parent_pages
        t.free_slot(1)
        t.free_slot(0)
        t.check_invariants()
        assert t.used_pages == 0


ops_strategy = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=4),   # admit/write/trim/fork/free
        st.integers(min_value=0, max_value=2),   # slot
        st.integers(min_value=1, max_value=14),  # prompt len / write / keep
        st.integers(min_value=1, max_value=6),   # max_new
    ),
    min_size=1, max_size=50,
)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestRollbackProperties:
    @settings(max_examples=60, deadline=None)
    @given(ops_strategy, st.integers(min_value=0, max_value=2**31 - 1))
    def test_fork_trim_never_leaks(self, ops, seed):
        """Arbitrary admit / speculative-write / trim / fork / free
        interleavings: ``check_invariants`` holds after every op and every
        page comes back at the end — trim after an arbitrary
        accepted-prefix length neither leaks nor double-frees."""
        rng = np.random.default_rng(seed)
        t = PagedTables(num_slots=3, num_blocks=5, num_pages=24, page_size=4)
        live = {}  # slot -> [prompt, written, limit]
        for op, slot, a, b in ops:
            if op == 0 and slot not in live and not t.tables[slot]:
                prompt = rng.integers(0, 97, size=a).tolist()
                if t.blocks_for(a + b) <= t.num_blocks:
                    shared = t.admit(slot, prompt, b)
                    if shared is not None:
                        live[slot] = [prompt, shared, a + b]
            elif op == 1 and slot in live:
                prompt, pos, limit = live[slot]
                n = min(a, limit - pos)
                if n > 0:
                    try:
                        t.prepare_write(slot, pos, n)
                    except OutOfPages:
                        pass  # fork-driven overcommit; invariants must hold
                    else:
                        live[slot][1] = pos + n
                        t.register_prompt_pages(slot, prompt, pos + n)
            elif op == 2 and slot in live:
                # roll back to an arbitrary accepted-prefix length
                keep = min(a, live[slot][1])
                t.trim(slot, keep)
                live[slot][1] = min(live[slot][1], keep)
            elif op == 3 and slot in live:
                child = next(
                    (c for c in range(3) if c not in live and not t.tables[c]),
                    None,
                )
                if child is not None:
                    t.fork(slot, child)
                    live[child] = [list(live[slot][0]), live[slot][1],
                                   live[slot][2]]
            elif op == 4 and slot in live:
                t.free_slot(slot)
                del live[slot]
            t.check_invariants()
        for slot in list(live):
            t.free_slot(slot)
        t.check_invariants()
        assert t.used_pages == 0
        assert all(r == 0 for r in t.ref)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=1, max_value=20),
           st.integers(min_value=0, max_value=20),
           st.integers(min_value=1, max_value=8))
    def test_trim_restores_write_capacity(self, written, keep, ps):
        """After trimming to any kept length, the slot can always re-write
        up to its admitted worst case (reservations were restored)."""
        t = PagedTables(num_slots=1, num_blocks=8, num_pages=8, page_size=ps)
        limit = min(written + 4, 8 * ps)
        written = min(written, limit)
        assert t.admit(0, list(range(written)), limit - written) == 0
        t.prepare_write(0, 0, written)
        keep = min(keep, written)
        t.trim(0, keep)
        t.check_invariants()
        t.prepare_write(0, keep, limit - keep)  # must not raise OutOfPages
        t.check_invariants()
