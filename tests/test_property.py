"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; skipping property tests")
from hypothesis import given, settings, strategies as st

from repro.core import DropConfig, accumulate_grads, drop_mask, make_grad_fn
from repro.core.theory import (
    effective_speedup,
    expected_completed_microbatches,
    expected_max_normal,
)
from repro.core.threshold import select_threshold

lat_arrays = st.lists(
    st.floats(min_value=0.01, max_value=10.0), min_size=2, max_size=16
).map(lambda xs: np.asarray(xs, np.float32))


@settings(max_examples=50, deadline=None)
@given(lat_arrays, st.floats(min_value=0.0, max_value=50.0), st.floats(min_value=0.0, max_value=10.0))
def test_drop_mask_monotone_in_tau(lat, tau, delta):
    """Raising the threshold never drops MORE micro-batches."""
    m1 = np.asarray(drop_mask(jnp.asarray(lat), tau, min_microbatches=0))
    m2 = np.asarray(drop_mask(jnp.asarray(lat), tau + delta, min_microbatches=0))
    assert (m2 >= m1).all()


@settings(max_examples=50, deadline=None)
@given(lat_arrays, st.floats(min_value=0.0, max_value=50.0))
def test_drop_mask_is_prefix(lat, tau):
    """Algorithm 1 stops and never resumes: the keep-mask is a prefix."""
    m = np.asarray(drop_mask(jnp.asarray(lat), tau, min_microbatches=0))
    k = int(m.sum())
    assert (m[:k] == 1).all() and (m[k:] == 0).all()


@settings(max_examples=30, deadline=None)
@given(
    st.floats(min_value=0.1, max_value=2.0),
    st.floats(min_value=0.01, max_value=0.5),
    st.integers(min_value=2, max_value=32),
)
def test_expected_microbatches_bounds(mu, sigma, m):
    """0 <= E[M~(tau)] <= M for any threshold."""
    for tau in (0.0, mu * m / 2, mu * m, mu * m * 10):
        v = expected_completed_microbatches(tau, mu, sigma, m)
        assert -1e-9 <= v <= m + 1e-9


@settings(max_examples=30, deadline=None)
@given(
    st.floats(min_value=0.1, max_value=2.0),
    st.floats(min_value=0.01, max_value=0.3),
    st.integers(min_value=2, max_value=512),
)
def test_expected_max_at_least_mean(mu, sigma, n):
    """E[max of N] >= mu, and non-decreasing in N."""
    e1 = expected_max_normal(mu, sigma, n)
    e2 = expected_max_normal(mu, sigma, 2 * n)
    assert e1 >= mu - 1e-9
    assert e2 >= e1 - 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=64), st.integers(min_value=2, max_value=12))
def test_threshold_selection_speedup_at_least_no_drop(n, m):
    """Algorithm 2 never returns a tau worse than 'never drop' (the grid
    includes max(T) so S_eff(tau_max) ~ 1)."""
    rng = np.random.default_rng(n * 100 + m)
    lat = rng.lognormal(-1.0, 0.6, size=(20, n, m))
    res = select_threshold(lat, tc=0.3)
    assert res.speedup >= 0.999


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=6), st.integers(min_value=1, max_value=4))
def test_accumulate_grads_linear_in_mask(m_keep, n_dims):
    """Gradients with 'computed' normalization equal the mean over kept
    micro-batches regardless of how many are kept."""
    m_total = 6
    rng = np.random.default_rng(m_keep * 10 + n_dims)
    xs = jnp.asarray(rng.normal(size=(m_total, 4, n_dims)).astype(np.float32))
    ys = jnp.asarray(rng.normal(size=(m_total, 4)).astype(np.float32))
    params = {"w": jnp.zeros((n_dims,), jnp.float32)}

    def loss(p, mb):
        return jnp.sum((mb["x"] @ p["w"] - mb["y"]) ** 2), jnp.asarray(4.0)

    mask = jnp.asarray([1.0] * m_keep + [0.0] * (m_total - m_keep))
    g, _, _ = accumulate_grads(
        make_grad_fn(loss), params, {"x": xs, "y": ys}, mask, DropConfig(normalize="computed")
    )
    kept_x = np.asarray(xs[:m_keep]).reshape(-1, n_dims)
    kept_y = np.asarray(ys[:m_keep]).reshape(-1)
    g_ref = 2 * kept_x.T @ (kept_x @ np.zeros(n_dims) - kept_y) / kept_x.shape[0]
    np.testing.assert_allclose(np.asarray(g["w"]), g_ref, rtol=1e-4, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    st.floats(min_value=0.3, max_value=1.0),
    st.floats(min_value=0.02, max_value=0.3),
    st.integers(min_value=4, max_value=16),
    st.integers(min_value=2, max_value=256),
    st.floats(min_value=0.0, max_value=2.0),
)
def test_effective_speedup_positive_finite(mu, sigma, m, n, tc):
    for tau in (0.6 * m * mu, m * mu, 2 * m * mu):
        s = effective_speedup(tau, mu, sigma, m, n, tc)
        assert np.isfinite(s) and s > 0


# ---------------------------------------------------------------------------
# Token-packed serving layout (repro.serve.packing)
# ---------------------------------------------------------------------------

# random slot/grant states: per active slot a write cursor and a grant of
# 0..8 tokens; slot indices unique by construction (dict keys)
grant_states = st.dictionaries(
    keys=st.integers(min_value=0, max_value=15),  # slot index
    values=st.tuples(
        st.integers(min_value=0, max_value=40),  # write cursor (first pos)
        st.lists(st.integers(min_value=0, max_value=999), max_size=8),
    ),
    max_size=8,
)


@settings(max_examples=100, deadline=None)
@given(grant_states, st.integers(min_value=0, max_value=16))
def test_packed_layout_invariants(state, slack):
    """Packing never overflows, scatters race-free, keeps positions
    contiguous per slot, and drops or duplicates no granted token."""
    from repro.serve.packing import PAD_SLOT, pack_step

    grants = [(slot, pos0, toks) for slot, (pos0, toks) in sorted(state.items())]
    total = sum(len(t) for _, _, t in grants)
    capacity = total + slack

    if capacity == 0:
        capacity = 1  # a (0,) compiled shape is never built
    lay = pack_step(grants, capacity)

    # entries never exceed the budgeted capacity; arrays are the capacity
    assert lay.n_tokens == total <= lay.capacity == capacity
    assert lay.tokens.shape == lay.slot_ids.shape == lay.positions.shape == (capacity,)
    # padding is exactly the tail and marked with PAD_SLOT
    assert (lay.slot_ids[total:] == PAD_SLOT).all()
    assert (lay.slot_ids[:total] >= 0).all()

    # scatter destinations (slot, position) are unique — race-free writes
    dests = list(zip(lay.slot_ids[:total].tolist(), lay.positions[:total].tolist()))
    assert len(set(dests)) == len(dests)

    # positions contiguous per slot from its cursor; tokens appear exactly
    # once, in grant order
    for slot, pos0, toks in grants:
        idx = np.flatnonzero(lay.slot_ids == slot)
        assert len(idx) == len(toks)
        np.testing.assert_array_equal(lay.positions[idx], pos0 + np.arange(len(toks)))
        np.testing.assert_array_equal(lay.tokens[idx], toks)
        if toks:
            assert lay.spans[slot] == (idx[0], len(toks))

    # without out_base the sampler key indices are all zero; with it,
    # each entry carries base + offset clamped at 0 (discarded prefill
    # columns), and padding entries stay zero
    assert (lay.out_idx == 0).all()
    bases = {slot: pos0 - 3 for slot, pos0, _ in grants}
    lay2 = pack_step(grants, capacity, out_base=bases)
    for slot, pos0, toks in grants:
        if not toks:
            continue
        j, m = lay2.spans[slot]
        np.testing.assert_array_equal(
            lay2.out_idx[j : j + m],
            np.maximum(bases[slot] + np.arange(m), 0),
        )
    assert (lay2.out_idx[total:] == 0).all()
    assert (lay2.out_idx >= 0).all()

    # overflow is loud, not truncating
    if total > 0:
        import pytest as _pytest

        with _pytest.raises(ValueError):
            pack_step(grants, total - 1)
