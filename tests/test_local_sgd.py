"""Local-SGD + DropCompute (appendix B.3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.local_sgd import StragglerScenario, localsgd_speedup, localsgd_train


class TestRuntimeModel:
    def test_localsgd_beats_sync_with_stragglers(self):
        sc = StragglerScenario(mode="uniform", p=0.04, delay=1.0, base=0.1)
        s, drop = localsgd_speedup(sc, n_workers=32, sync_period=8)
        assert s > 1.2
        assert drop == 0.0

    def test_dropcompute_helps_single_server_stragglers(self):
        """fig. 12 right: one bad host makes Local-SGD behave nearly
        synchronously; DropCompute recovers the speedup."""
        sc = StragglerScenario(mode="single_server", p=0.3, delay=1.0, base=0.1, server_size=4)
        s_plain, _ = localsgd_speedup(sc, n_workers=32, sync_period=8)
        tau = 8 * 0.1 * 1.6  # cap each period at ~1.6x the clean compute
        s_drop, drop = localsgd_speedup(sc, n_workers=32, sync_period=8, tau=tau)
        assert s_drop > s_plain
        assert 0.0 < drop < 0.2

    def test_longer_period_amortizes_uniform_stragglers(self):
        sc = StragglerScenario(mode="uniform", p=0.04, delay=1.0, base=0.1)
        s2, _ = localsgd_speedup(sc, 32, 2)
        s16, _ = localsgd_speedup(sc, 32, 16)
        assert s16 > s2


class TestFunctionalTrainer:
    def test_converges_on_quadratic(self):
        rng = np.random.default_rng(0)
        w_true = rng.normal(size=(4,)).astype(np.float32)

        def data_fn(r, n):
            rr = np.random.default_rng(100 * r + n)
            x = rr.normal(size=(6, 8, 4)).astype(np.float32)  # H=6 local steps
            y = x @ w_true
            return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

        def loss(p, mb):
            return jnp.mean((mb["x"] @ p["w"] - mb["y"]) ** 2)

        p0 = {"w": jnp.zeros((4,), jnp.float32)}
        final, losses = localsgd_train(loss, p0, data_fn, n_workers=4, rounds=20,
                                       sync_period=6, lr=0.05)
        assert losses[-1] < 0.05 * losses[0]
        np.testing.assert_allclose(np.asarray(final["w"]), w_true, atol=0.1)

    def test_dropped_steps_still_converge(self):
        """DropCompute on local steps: randomly skip ~20% of local steps —
        convergence survives (the B.3 claim)."""
        rng = np.random.default_rng(0)
        w_true = rng.normal(size=(4,)).astype(np.float32)

        def data_fn(r, n):
            rr = np.random.default_rng(100 * r + n)
            x = rr.normal(size=(6, 8, 4)).astype(np.float32)
            y = x @ w_true
            return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

        def loss(p, mb):
            return jnp.mean((mb["x"] @ p["w"] - mb["y"]) ** 2)

        keep = (np.random.default_rng(1).random((20, 4, 6)) > 0.2).astype(np.float32)
        p0 = {"w": jnp.zeros((4,), jnp.float32)}
        final, losses = localsgd_train(loss, p0, data_fn, n_workers=4, rounds=20,
                                       sync_period=6, lr=0.05, keep_mask=keep)
        assert losses[-1] < 0.1 * losses[0]
