"""Token-packed serving step: packed-vs-dense parity, typed pattern
errors, and the slow-lane packed soak.

The dense (B, chunk_size) engine is the oracle: for every point on the
parity matrix (budget x chunk x mixed prompt lengths) the packed engine
must produce identical greedy output streams, TTFT step counts, and
per-step scheduled/deferred-token accounting — packing changes *which
compute runs*, never *what is computed*.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import (
    UnsupportedPatternError,
    init_decode_cache,
    init_params,
    packed_prefill,
    prefill_chunk,
)
from repro.serve import ContinuousBatcher, Request, pack_step, packed_capacity

CFG = ModelConfig(
    name="serve-packed-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab_size=101, layer_pattern="LG", sliding_window=6, dtype="float32", remat=False,
)

# mixed prompt lengths through 2 slots: forces slot reuse and mixed
# decode+prefill steps (the shapes where packing actually differs)
PROMPT_LENS = (3, 5, 12, 4, 8)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def make_prompts(seed=0, lens=PROMPT_LENS):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=n).tolist() for n in lens]


def run_engine(params, prompts, packed, max_new=4, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 24)
    eng = ContinuousBatcher(params, CFG, packed=packed, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
    eng.run()
    return eng


class TestPackedDenseParity:
    """Dense engine as oracle across the budget x chunk matrix."""

    @pytest.mark.parametrize("budget", [None, 4, 16])
    @pytest.mark.parametrize("chunk", [4, 16])
    def test_parity_matrix(self, params, budget, chunk):
        prompts = make_prompts()
        dense = run_engine(params, prompts, packed=False,
                           chunk_size=chunk, token_budget=budget)
        packd = run_engine(params, prompts, packed=True,
                           chunk_size=chunk, token_budget=budget)

        # identical greedy output streams (byte-identical token ids)
        assert {u: r.output for u, r in dense.finished.items()} == {
            u: r.output for u, r in packd.finished.items()
        }
        # identical TTFT step counts per request
        assert {u: r.ttft_steps for u, r in dense.finished.items()} == {
            u: r.ttft_steps for u, r in packd.finished.items()
        }
        # identical per-step scheduling and deferral accounting
        assert dense.steps == packd.steps
        for sd, sp in zip(dense.step_stats, packd.step_stats):
            assert (sd.decode_tokens, sd.prefill_tokens, sd.deferred_tokens) == (
                sp.decode_tokens, sp.prefill_tokens, sp.deferred_tokens
            )

    def test_parity_token_streaming_chunk1(self, params):
        """chunk=1 is the seed token-streaming degenerate case."""
        prompts = make_prompts(seed=1, lens=(3, 6, 4))
        dense = run_engine(params, prompts, packed=False, chunk_size=1)
        packd = run_engine(params, prompts, packed=True, chunk_size=1)
        assert {u: r.output for u, r in dense.finished.items()} == {
            u: r.output for u, r in packd.finished.items()
        }

    def test_packed_capacity_is_the_compiled_shape(self, params):
        """The packed program shape is capacity, not (B, chunk)."""
        assert packed_capacity(2, 4, None) == 8
        assert packed_capacity(2, 4, 4) == 5
        assert packed_capacity(8, 16, 4) == 9  # decode slots dominate
        eng = run_engine(params, make_prompts(seed=2, lens=(5,)), packed=True,
                         chunk_size=4, token_budget=4)
        assert eng.packed_capacity == 5

    def test_packed_budget_never_overflows_capacity(self, params):
        """Every step's granted tokens fit the compiled packed shape
        (pack_step raises on overflow, so completing is the assertion);
        also check the accounting against the documented bound."""
        prompts = make_prompts(seed=3, lens=(20, 3, 3, 18))
        eng = run_engine(params, prompts, packed=True, batch_slots=3,
                         max_len=32, chunk_size=8, token_budget=4, max_new=6)
        for s in eng.step_stats:
            assert s.scheduled_tokens <= eng.packed_capacity
            assert s.scheduled_tokens <= max(s.decode_tokens, 4) + 1


class TestPackingLayout:
    """Deterministic layout checks (the hypothesis sweep lives in
    test_property.py)."""

    def test_pack_step_layout(self):
        grants = [(0, 5, [11]), (2, 0, [21, 22, 23]), (1, 7, [31, 32])]
        lay = pack_step(grants, capacity=8)
        assert lay.n_tokens == 6 and lay.capacity == 8
        np.testing.assert_array_equal(
            lay.tokens, [11, 21, 22, 23, 31, 32, 0, 0])
        np.testing.assert_array_equal(
            lay.slot_ids, [0, 2, 2, 2, 1, 1, -1, -1])
        np.testing.assert_array_equal(
            lay.positions, [5, 0, 1, 2, 7, 8, 0, 0])
        np.testing.assert_array_equal(lay.segment_starts, [0, 1, 4, 6])
        assert lay.spans == {0: (0, 1), 2: (1, 3), 1: (4, 2)}

    def test_pack_step_overflow_raises(self):
        with pytest.raises(ValueError, match="overflow"):
            pack_step([(0, 0, [1, 2, 3])], capacity=2)

    def test_zero_token_grants_occupy_nothing(self):
        lay = pack_step([(0, 4, []), (1, 0, [7])], capacity=2)
        assert lay.n_tokens == 1 and lay.spans == {1: (0, 1)}


class TestPackedModelPath:
    """packed_prefill vs prefill_chunk at the model level."""

    def test_packed_matches_chunked(self, params):
        prompts = make_prompts(seed=4, lens=(7, 3))
        b, max_len, chunk = 2, 24, 4
        # dense chunked reference
        cache_d = init_decode_cache(params, CFG, b, max_len, linear=True)
        cache_p = init_decode_cache(params, CFG, b, max_len, linear=True)
        pos = [0, 0]
        last_d, last_p = {}, {}
        while any(pos[i] < len(prompts[i]) for i in range(b)):
            toks = np.zeros((b, chunk), np.int32)
            lens = np.zeros(b, np.int32)
            grants = []
            for i, p in enumerate(prompts):
                n = min(chunk, len(p) - pos[i])
                lens[i] = n
                toks[i, :n] = p[pos[i]: pos[i] + n]
                if n:
                    grants.append((i, pos[i], p[pos[i]: pos[i] + n]))
            lg_d, cache_d = prefill_chunk(
                params, CFG, cache_d, jnp.asarray(toks),
                jnp.asarray(pos, jnp.int32), jnp.asarray(lens))
            jax.block_until_ready(lg_d)
            lay = pack_step(grants, capacity=b * chunk)
            lg_p, cache_p = packed_prefill(
                params, CFG, cache_p, jnp.asarray(lay.tokens),
                jnp.asarray(lay.slot_ids), jnp.asarray(lay.positions))
            jax.block_until_ready(lg_p)
            for i, p in enumerate(prompts):
                if lens[i] and pos[i] + lens[i] == len(p):
                    last_d[i] = np.asarray(lg_d[i, lens[i] - 1])
                    j0, m = lay.spans[i]
                    last_p[i] = np.asarray(lg_p[j0 + m - 1])
                pos[i] += int(lens[i])
        for i in last_d:
            np.testing.assert_allclose(last_p[i], last_d[i], atol=1e-5)
            assert int(last_p[i].argmax()) == int(last_d[i].argmax())


class TestUnsupportedPatternTyped:
    """Non-chunkable configs raise the typed error cleanly (asserts would
    vanish under python -O).  'R'/'M' patterns chunk-scan through the
    serving paths now; what remains unservable is bidirectional 'B'
    layers (no causal cache) and, for recurrent patterns, speculative
    decoding (carried state cannot roll back rejected drafts)."""

    @pytest.mark.parametrize("pattern", ["R", "M"])
    def test_spec_with_recurrent_raises(self, pattern):
        from repro.serve.spec import NGramProposer, SpecConfig

        bad = ModelConfig(name="bad", n_layers=2, d_model=32, n_heads=2,
                          n_kv_heads=1, d_ff=64, vocab_size=101,
                          layer_pattern=pattern, dtype="float32", remat=False)
        with pytest.raises(UnsupportedPatternError, match="roll back"):
            ContinuousBatcher({}, bad, batch_slots=1, max_len=8,
                              spec=SpecConfig(proposer=NGramProposer()))

    @pytest.mark.parametrize("fn", [prefill_chunk, packed_prefill])
    @pytest.mark.parametrize("pattern", ["BG", "B"])
    def test_model_paths_raise(self, fn, pattern):
        bad = ModelConfig(name="bad", n_layers=2, d_model=32, n_heads=2,
                          n_kv_heads=1, d_ff=64, vocab_size=101,
                          layer_pattern=pattern, dtype="float32", remat=False)
        with pytest.raises(UnsupportedPatternError, match="layer patterns"):
            fn({}, bad, {}, jnp.zeros((4,) if fn is packed_prefill else (1, 4), jnp.int32),
               jnp.zeros((1,), jnp.int32), jnp.ones((1,), jnp.int32))

    def test_is_typed_not_assert(self):
        assert issubclass(UnsupportedPatternError, NotImplementedError)
        assert not issubclass(UnsupportedPatternError, AssertionError)


@pytest.mark.slow
class TestPackedSoak:
    """End-to-end packed serving soak: 64 staggered requests."""

    def test_soak_no_starvation_budget_honored(self, params):
        rng = np.random.default_rng(7)
        budget, slots, chunk, max_len = 12, 8, 16, 64
        eng = ContinuousBatcher(params, CFG, batch_slots=slots, max_len=max_len,
                                chunk_size=chunk, token_budget=budget, packed=True)
        lens = rng.integers(4, 40, size=64)
        pending = [
            Request(uid=i, prompt=rng.integers(0, CFG.vocab_size, size=n).tolist(),
                    max_new_tokens=8)
            for i, n in enumerate(lens)
        ]
        # staggered arrivals: a few new requests every few steps
        while pending or eng.busy:
            for _ in range(3):
                if pending:
                    eng.submit(pending.pop(0))
            for _ in range(4):
                if eng.busy:
                    eng.step()
        done = eng.finished
        # no starvation: every request finished and emitted its tokens
        assert sorted(done) == list(range(64))
        assert all(len(r.output) == 8 for r in done.values())
        assert all(r.ttft_steps is not None for r in done.values())
        for s in eng.step_stats:
            # budget honored: decode is unconditional, prefill fills the
            # remainder, the starvation guard may add one token
            assert s.scheduled_tokens <= max(s.decode_tokens, budget) + 1
            assert s.scheduled_tokens <= eng.packed_capacity
            # starvation guard: whenever prefill work waited, some ran
            if s.deferred_tokens > 0:
                assert s.prefill_tokens >= 1
