"""Algorithm 2 (automatic threshold selection) tests."""
import numpy as np
import pytest

from repro.core import LatencyModel, NoiseModel, select_threshold, simulate


def profile(workers=32, m=12, iters=100, kind="paper_lognormal", seed=0):
    model = LatencyModel(base=0.45, noise=NoiseModel(kind=kind))
    return simulate(model, iters, workers, m, tc=0.5, seed=seed)


class TestSelectThreshold:
    def test_matches_bruteforce(self):
        sim = profile()
        res = select_threshold(sim.t, sim.tc, grid_size=128)
        # brute force over the same grid using SimResult.effective_speedup
        best = max(res.grid, key=lambda tau: sim.effective_speedup(tau))
        assert res.tau == pytest.approx(best)
        assert res.speedup == pytest.approx(sim.effective_speedup(best), rel=1e-9)

    def test_speedup_above_one_with_heavy_tail(self):
        """In the paper's simulated-delay environment DropCompute should
        find a threshold with S_eff well above 1 (§5.2 reports 1.13-1.18)."""
        sim = profile(workers=64)
        res = select_threshold(sim.t, sim.tc)
        assert res.speedup > 1.05
        # and only a small fraction of micro-batches is dropped
        comp = res.completion[np.argmax(res.speedups)]
        assert comp > 0.8

    def test_no_variance_no_gain(self):
        """Deterministic compute: the best threshold drops ~nothing."""
        sim = profile(kind="none")
        res = select_threshold(sim.t, sim.tc)
        assert res.speedup == pytest.approx(1.0, abs=0.02)

    def test_all_workers_agree(self):
        """Decentralization: the selection is a pure function of the shared
        profile — every worker computes the same tau*."""
        sim = profile(workers=8, iters=50)
        r1 = select_threshold(sim.t, sim.tc)
        r2 = select_threshold(sim.t.copy(), float(sim.tc))
        assert r1.tau == r2.tau

    def test_shapes_validated(self):
        with pytest.raises(ValueError):
            select_threshold(np.ones((3, 4)), 0.1)
