"""Algorithm 2 (automatic threshold selection) tests."""
import numpy as np
import pytest

from repro.core import LatencyModel, NoiseModel, select_threshold, simulate


def profile(workers=32, m=12, iters=100, kind="paper_lognormal", seed=0):
    model = LatencyModel(base=0.45, noise=NoiseModel(kind=kind))
    return simulate(model, iters, workers, m, tc=0.5, seed=seed)


class TestSelectThreshold:
    def test_matches_bruteforce(self):
        sim = profile()
        res = select_threshold(sim.t, sim.tc, grid_size=128)
        # brute force over the same grid using SimResult.effective_speedup
        best = max(res.grid, key=lambda tau: sim.effective_speedup(tau))
        assert res.tau == pytest.approx(best)
        assert res.speedup == pytest.approx(sim.effective_speedup(best), rel=1e-9)

    def test_speedup_above_one_with_heavy_tail(self):
        """In the paper's simulated-delay environment DropCompute should
        find a threshold with S_eff well above 1 (§5.2 reports 1.13-1.18)."""
        sim = profile(workers=64)
        res = select_threshold(sim.t, sim.tc)
        assert res.speedup > 1.05
        # and only a small fraction of micro-batches is dropped
        comp = res.completion[np.argmax(res.speedups)]
        assert comp > 0.8

    def test_no_variance_no_gain(self):
        """Deterministic compute: the best threshold drops ~nothing."""
        sim = profile(kind="none")
        res = select_threshold(sim.t, sim.tc)
        assert res.speedup == pytest.approx(1.0, abs=0.02)

    def test_all_workers_agree(self):
        """Decentralization: the selection is a pure function of the shared
        profile — every worker computes the same tau*."""
        sim = profile(workers=8, iters=50)
        r1 = select_threshold(sim.t, sim.tc)
        r2 = select_threshold(sim.t.copy(), float(sim.tc))
        assert r1.tau == r2.tau

    def test_shapes_validated(self):
        with pytest.raises(ValueError):
            select_threshold(np.ones((3, 4)), 0.1)


class TestWithThresholdMatchesDropMask:
    """Regression: the simulator and the in-graph mask agree exactly.

    SimResult.with_threshold used to ignore min_microbatches, reporting 0
    completed micro-batches for tiny tau while drop_mask guarantees >= 1.
    """

    def test_completed_fraction_agrees_for_all_tau(self):
        import jax.numpy as jnp

        from repro.core import drop_mask

        sim = profile(workers=4, m=6, iters=20)
        taus = [0.0, 0.1, 0.5, 1.0, 2.0, 3.0, 1e9]
        for tau in taus:
            _, frac = sim.with_threshold(tau)
            mask = np.asarray(drop_mask(jnp.asarray(sim.t), tau, min_microbatches=1))
            np.testing.assert_allclose(frac, mask.sum(-1).mean(-1) / sim.t.shape[-1])

    def test_tiny_tau_keeps_min_microbatches(self):
        sim = profile(workers=4, m=6, iters=10)
        t_iter, frac = sim.with_threshold(0.0)
        # every worker still computes its first micro-batch...
        assert (frac == 1.0 / 6).all()
        # ...and the iteration lasts as long as the slowest forced micro-batch
        np.testing.assert_allclose(t_iter, sim.t[:, :, 0].max(axis=-1) + sim.tc)

    def test_min_microbatches_zero_restores_raw_mask(self):
        sim = profile(workers=4, m=6, iters=10)
        _, frac = sim.with_threshold(0.0, min_microbatches=0)
        assert (frac == 0.0).all()

    def test_select_threshold_uses_same_floor(self):
        """Alg. 2 brute-force pin holds with the floor applied on both sides."""
        sim = profile(workers=8, m=6, iters=30)
        grid = np.linspace(0.0, float(sim.T.max()) * 1.1, 64)
        res = select_threshold(sim.t, sim.tc, grid=grid)
        brute = np.array([sim.effective_speedup(t) for t in grid])
        np.testing.assert_allclose(res.speedups, brute, rtol=1e-12)
