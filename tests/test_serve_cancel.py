"""Mid-flight cancellation: ``ContinuousBatcher.cancel`` at every point
of a request's life — queued, mid-prefill, mid-decode — on both KV
layouts.

The invariants under test: a cancel frees the slot for the next queued
request, decrefs every page the slot held (shared prefix pages survive
for their other owners, registered prompt pages fall to the reclaimable
cached tier, the partially-written tail page returns to the free list),
``PagedTables.check_invariants`` stays clean after every cancel, and the
engine drains to **zero referenced pages**.  Cancellation must also be
invisible to everyone else: survivors' outputs stay byte-identical to a
run that never contained the cancelled request.
"""
import jax
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import init_params
from repro.serve import ContinuousBatcher, Request

CFG = ModelConfig(
    name="serve-cancel-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=64, vocab_size=101, layer_pattern="LG", sliding_window=6,
    dtype="float32", remat=False,
)

PAGE = 4


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def make_prompts(seed=0, lens=(3, 5, 12, 4, 8)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=n).tolist() for n in lens]


def make_engine(params, cache="paged", **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 24)
    kw.setdefault("chunk_size", 4)
    if cache == "paged":
        kw.setdefault("page_size", PAGE)
    return ContinuousBatcher(params, CFG, cache=cache, **kw)


def submit_all(eng, prompts, max_new=4):
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    return reqs


def drain(eng):
    while eng.busy:
        eng.step()
    if eng.kv is not None:
        eng.kv.check_invariants()
        assert eng.kv.tables.used_pages == 0


def oracle_outputs(params, prompts, max_new=4, skip=()):
    """Dense-engine outputs for the same workload minus the cancelled
    uids — what survivors must still produce."""
    eng = make_engine(params, cache="dense")
    for i, p in enumerate(prompts):
        if i not in skip:
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
    eng.run()
    return {u: r.output for u, r in eng.finished.items()}


@pytest.mark.parametrize("cache", ["dense", "paged"])
class TestCancelLifecycle:
    def test_cancel_queued(self, params, cache):
        """Cancelling a request still in the admission queue: it never
        reaches a slot, never produces tokens, and survivors match a run
        that never saw it."""
        prompts = make_prompts()
        eng = make_engine(params, cache=cache)
        reqs = submit_all(eng, prompts)
        assert eng.cancel(4) is True  # 5 requests, 2 slots: uid 4 is queued
        drain(eng)
        assert reqs[4].cancelled and reqs[4].output == []
        assert reqs[4].finished_at is not None
        assert 4 not in eng.finished and 4 in eng.cancelled
        assert {u: r.output for u, r in eng.finished.items()} == \
            oracle_outputs(params, prompts, skip={4})
        assert eng.stats_summary()["cancelled"] == 1.0

    def test_cancel_mid_prefill(self, params, cache):
        """uid 2 (12-token prompt, chunk 4) needs 3 prefill steps: cancel
        after one step, while its slot holds a partially-written chain."""
        prompts = make_prompts()
        eng = make_engine(params, cache=cache)
        reqs = submit_all(eng, prompts)
        while reqs[2].admitted_at is None:
            eng.step()
        # 12-token prompt through chunk 4: admission step wrote at most
        # one chunk, so the slot holds a partially-written chain
        assert reqs[2].first_token_at is None
        before = eng.kv.tables.used_pages if eng.kv is not None else 0
        assert eng.cancel(2) is True
        if eng.kv is not None:
            eng.kv.check_invariants()
            assert eng.kv.tables.used_pages < before  # tail page came back
        drain(eng)
        assert reqs[2].cancelled and reqs[2].output == []
        assert {u: r.output for u, r in eng.finished.items()} == \
            oracle_outputs(params, prompts, skip={2})

    def test_cancel_mid_decode(self, params, cache):
        """Cancel after the first token: tokens already emitted stay on
        the request, the slot frees for the next queued uid, and the
        stream never grows again."""
        prompts = make_prompts()
        eng = make_engine(params, cache=cache, batch_slots=1)
        reqs = submit_all(eng, prompts, max_new=6)
        while reqs[0].first_token_at is None:
            eng.step()
        emitted = len(reqs[0].output)
        assert eng.cancel(0) is True
        if eng.kv is not None:
            eng.kv.check_invariants()
        drain(eng)
        assert reqs[0].cancelled and len(reqs[0].output) == emitted < 6
        assert set(eng.finished) == {1, 2, 3, 4}
        assert {u: r.output for u, r in eng.finished.items()} == \
            oracle_outputs(params, prompts, max_new=6, skip={0})

    def test_cancel_unknown_and_finished(self, params, cache):
        eng = make_engine(params, cache=cache)
        reqs = submit_all(eng, make_prompts()[:2])
        assert eng.cancel(99) is False
        eng.run()
        assert eng.cancel(reqs[0].uid) is False  # already finished
        assert eng.stats_summary()["cancelled"] == 0.0
        drain(eng)


class TestCancelSharedPages:
    def test_cancel_keeps_shared_prefix_alive(self, params):
        """Two live requests mapping the same registered prefix pages;
        cancelling one must decref, not free — the survivor keeps
        decoding from the shared pages and matches the dense oracle."""
        rng = np.random.default_rng(7)
        prefix = rng.integers(0, CFG.vocab_size, size=2 * PAGE).tolist()
        tails = [rng.integers(0, CFG.vocab_size, size=4).tolist()
                 for _ in range(3)]
        prompts = [prefix + t for t in tails]

        eng = make_engine(params, cache="paged", chunk_size=PAGE)
        # seed the prefix cache: run the first request to completion so
        # its prompt pages land in the registered (reclaimable) tier
        eng.submit(Request(uid=0, prompt=list(prompts[0]), max_new_tokens=2))
        eng.run()
        assert eng.kv.tables.used_pages == 0
        assert eng.kv.tables.cached_pages > 0

        # B and C admit together and both map the cached prefix pages
        b = Request(uid=1, prompt=list(prompts[1]), max_new_tokens=6)
        c = Request(uid=2, prompt=list(prompts[2]), max_new_tokens=6)
        eng.submit(b)
        eng.submit(c)
        eng.step()
        assert b.admitted_at is not None
        assert sum(s.shared_tokens for s in eng.step_stats) >= 2 * PAGE
        assert eng.cancel(1) is True  # B mid-flight, sharing pages with C
        eng.kv.check_invariants()
        drain(eng)
        # C mapped the same prefix pages (before or after the cancel —
        # either way they had to survive B's decref) and decodes right
        assert sum(s.shared_tokens for s in eng.step_stats) >= 2 * 2 * PAGE
        assert c.output == oracle_outputs(
            params, prompts, max_new=6, skip={0, 1})[2]
        assert b.cancelled and 2 in eng.finished

    def test_interleaved_cancels_drain_clean(self, params):
        """Stress the reclaim path: heavier traffic through a small page
        pool, cancelling every third uid at varied life stages; the pool
        must conserve pages after every cancel and drain to zero."""
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, CFG.vocab_size, size=n).tolist()
                   for n in (6, 9, 12, 5, 8, 10, 7, 11)]
        eng = make_engine(params, cache="paged", batch_slots=3)
        reqs = submit_all(eng, prompts, max_new=5)
        cancelled = []
        k = 0
        while eng.busy:
            eng.step()
            k += 1
            uid = (3 * k) % len(reqs)
            if not reqs[uid].cancelled and reqs[uid].finished_at is None:
                if eng.cancel(uid):
                    cancelled.append(uid)
                    eng.kv.check_invariants()
        assert cancelled  # the schedule above always catches some live
        drain(eng)
        survivors = sorted(set(range(len(reqs))) - set(cancelled))
        assert sorted(eng.finished) == survivors
        assert eng.stats_summary()["cancelled"] == float(len(cancelled))
        want = oracle_outputs(params, prompts, max_new=5, skip=set(cancelled))
        assert {u: eng.finished[u].output for u in survivors} == want
