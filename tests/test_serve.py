"""Continuous-batching scheduler: correctness vs sequential decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import decode_step, init_decode_cache, init_params
from repro.serve import ContinuousBatcher, InvalidRequestError, Request

pytestmark = pytest.mark.slow  # full-lane only; tier-1 covers this path via faster tests

CFG = ModelConfig(
    name="serve-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab_size=101, layer_pattern="LG", sliding_window=6, dtype="float32", remat=False,
)


def sequential_reference(params, prompt, max_new, max_len):
    """Decode one request alone, token by token."""
    cache = init_decode_cache(params, CFG, 1, max_len)
    out = []
    tok = None
    for t in range(len(prompt) + max_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        logits, cache = decode_step(
            params, CFG, cache, jnp.asarray([[cur]], jnp.int32), jnp.int32(t)
        )
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0, -1])))
    return out[:max_new]


class TestContinuousBatching:
    def setup_method(self):
        self.params = init_params(jax.random.PRNGKey(0), CFG)
        rng = np.random.default_rng(0)
        self.prompts = [list(rng.integers(0, 101, size=n)) for n in (3, 5, 8, 4, 6, 7)]

    def test_matches_sequential(self):
        eng = ContinuousBatcher(self.params, CFG, batch_slots=2, max_len=24)
        for i, p in enumerate(self.prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=5))
        done = eng.run()
        assert sorted(done) == list(range(len(self.prompts)))
        for i, p in enumerate(self.prompts):
            ref = sequential_reference(self.params, p, 5, 24)
            assert done[i].output == ref, (i, done[i].output, ref)

    def test_slots_reused(self):
        eng = ContinuousBatcher(self.params, CFG, batch_slots=2, max_len=24)
        for i in range(5):
            eng.submit(Request(uid=i, prompt=[1, 2, 3], max_new_tokens=3))
        done = eng.run()
        assert len(done) == 5  # 5 requests through 2 slots

    def test_rejects_too_long(self):
        eng = ContinuousBatcher(self.params, CFG, batch_slots=1, max_len=8)
        # typed (survives python -O), not the seed's bare assert
        with pytest.raises(InvalidRequestError):
            eng.submit(Request(uid=0, prompt=list(range(7)), max_new_tokens=5))
