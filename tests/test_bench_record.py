"""The committed serving perf record (``BENCH_serve.json``) parses and
carries every engine mode — the repo's benchmark trajectory is a
contract, not a dropping.

CI regenerates the record in the full lane (``serve_throughput.py
--packed --spec --json``); this tier-1 check pins the committed copy so
a PR can't silently drop a mode (the speculative row in particular) or
break the schema consumers parse.
"""
import json
import math
import os

import pytest

BENCH = os.path.join(os.path.dirname(__file__), os.pardir, "BENCH_serve.json")


@pytest.fixture(scope="module")
def record():
    assert os.path.exists(BENCH), "BENCH_serve.json missing at the repo root"
    with open(BENCH) as f:
        return json.load(f)


class TestBenchRecord:
    def test_all_modes_present(self, record):
        modes = {r["mode"] for r in record["rows"]}
        assert modes == {"dense", "packed", "paged", "paged-int8", "spec",
                         "sampled-dense", "sampled", "spec-sampled",
                         "moe-packed", "recurrent-chunked"}, modes

    def test_rows_carry_steps_per_token(self, record):
        for r in record["rows"]:
            assert math.isfinite(r["steps_per_token"]), r

    def test_spec_rows_parse(self, record):
        for mode in ("spec", "spec-sampled"):
            spec_rows = [r for r in record["rows"] if r["mode"] == mode]
            assert spec_rows, mode
            for r in spec_rows:
                assert 0.0 <= r["acceptance_rate"] <= 1.0
                assert r["draft_tokens"] >= 0

    def test_sampled_rows_carry_params_and_throughput(self, record):
        """The sampled trio is the greedy-vs-sampled throughput
        trajectory: rows must pin the sampling params (so the record is
        comparable across PRs) and carry finite tok/s; spec-sampled is
        the acceptance-rate-under-sampling signal."""
        sampled = [r for r in record["rows"]
                   if r["mode"] in ("sampled-dense", "sampled",
                                    "spec-sampled")]
        assert sampled
        for r in sampled:
            assert r["sampling"] == {"temperature": 0.8, "top_k": 0,
                                     "top_p": 0.95}, r
            assert math.isfinite(r["tokens_per_s"]) and r["tokens_per_s"] > 0
        greedy_modes = {r["mode"] for r in record["rows"]
                        if "sampling" not in r}
        assert greedy_modes == {"dense", "packed", "paged", "paged-int8",
                                "spec", "moe-packed", "recurrent-chunked"}

    def test_model_zoo_rows(self, record):
        """The one-engine-every-architecture rows: the recurrent row
        pins chunk-scan == decode-oracle parity; the MoE row pins the
        cf=inf dense-parity flag and carries the dropped-route count
        (per-expert tau accounting) at the recorded capacity factor."""
        by_mode = {r["mode"]: r for r in record["rows"]}
        rec = by_mode["recurrent-chunked"]
        assert rec["decode_oracle_match"] is True
        assert set(rec["pattern"]) <= {"R", "M"}  # actually recurrent
        moe = by_mode["moe-packed"]
        assert moe["cf_inf_matches_dense"] is True
        assert moe["capacity_factor"] > 0
        assert moe["expert_overflow_tokens"] >= 0

    def test_speculative_record_clears_bar(self, record):
        """The acceptance criterion: >= 1.5x fewer engine steps per
        generated token with the n-gram proposer on repetitive prompts."""
        rec = record["speculative"]
        assert rec["proposer"] == "ngram" and rec["k"] >= 1
        assert 0.0 <= rec["acceptance_rate"] <= 1.0
        assert rec["step_reduction"] >= 1.5
        ratio = rec["steps_per_token"]["greedy"] / rec["steps_per_token"]["spec"]
        assert ratio == pytest.approx(rec["step_reduction"])

    def test_prefix_sharing_record_present(self, record):
        rec = record["prefix_sharing"]
        assert rec["second_request_prefill_steps"]["shared"] < \
            rec["second_request_prefill_steps"]["disjoint"]

    def test_paged_decode_step_not_regressed(self, record):
        """The bugfix gate: with the fused paged read the paged engine's
        pure-decode step must stay within 1.25x of dense at the largest
        recorded budget (it was 1.77x with the gather materialization)."""
        budgets = [r["budget"] for r in record["rows"] if r["budget"]]
        hi = max(budgets)
        by_mode = {r["mode"]: r for r in record["rows"] if r["budget"] == hi}
        dense, paged = by_mode["dense"], by_mode["paged"]
        assert math.isfinite(paged["decode_step_ms"])
        assert paged["decode_step_ms"] <= 1.25 * dense["decode_step_ms"], (
            f"paged decode {paged['decode_step_ms']:.2f} ms vs dense "
            f"{dense['decode_step_ms']:.2f} ms at budget={hi}"
        )

    def test_traffic_record_present(self, record):
        """The open-loop traffic replay record (``traffic_replay.py``):
        >= 1000 requests through the async front-end, tail-latency
        percentiles split queue-wait vs post-admission, deadline goodput
        accounted, and zero leaked pages after drain."""
        rec = record["traffic"]
        assert rec["requests"] >= 1000
        assert sum(rec["outcomes"].values()) == rec["requests"]
        assert rec["arrival"]["process"] == "poisson"
        for dist in ("ttft_ms", "queue_wait_ms", "admitted_ttft_ms",
                     "tpot_ms"):
            assert rec[dist]["p50"] <= rec[dist]["p99"], dist
            assert math.isfinite(rec[dist]["p99"]), dist
        good = rec["goodput"]
        assert 0.0 <= good["met_fraction"] <= 1.0
        assert good["met_tokens_per_s"] <= good["tokens_per_s"]
        assert rec["prefix"]["grouped_requests"] > 0
        assert rec["engine"]["shared_prompt_tokens"] > 0  # Zipf prefixes hit
        assert rec["leaked_pages"] == 0
        # the replay exercises the sampling path with per-request seeds
        assert rec["sampling"]["temperature"] > 0
        assert rec["sampling"]["per_request_seeds"] is True

    def test_int8_rows_and_admission_record(self, record):
        """int8 rows carry a token-match rate (the allclose tier) and the
        admission record shows ~2x pages at fixed pool bytes."""
        int8_rows = [r for r in record["rows"] if r["mode"] == "paged-int8"]
        assert int8_rows
        for r in int8_rows:
            assert 0.9 <= r["token_match"] <= 1.0
        adm = record["int8_admission"]
        assert adm["pages"]["int8"] >= 1.6 * adm["pages"]["bfloat16"]
        assert adm["admitted_requests"]["int8"] >= \
            adm["admitted_requests"]["bfloat16"]
