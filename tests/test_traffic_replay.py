"""Traffic-replay harness: seeded workload determinism, distribution
shape, and a small end-to-end replay (the tier-1 smoke behind the CI
``traffic`` record).

Determinism is the contract that makes the benchmark a regression
signal: the same ``(args, seed)`` must produce token-identical request
sets with identical arrival times, across processes and PRs.  The same
holds for the shared ``benchmarks/common.py`` generators every serving
benchmark and example draws from.
"""
import json
import os
import sys

import pytest

sys.path.insert(
    0, os.path.join(os.path.dirname(__file__), os.pardir, "benchmarks")
)
import common  # noqa: E402
import traffic_replay  # noqa: E402

VOCAB = 1003


def small_workload(seed=5, n=200):
    return traffic_replay.build_workload(
        n, VOCAB, seed, rps=100.0, prefix_groups=8, prefix_len=16,
        prompt_median=24, max_prompt=64, out_median=6, max_new=16,
        deadline_s=2.0,
    )


class TestWorkloadGeneration:
    def test_same_seed_same_workload(self):
        assert small_workload() == small_workload()

    def test_different_seed_differs(self):
        a, b = small_workload(seed=5), small_workload(seed=6)
        assert [w.prompt for w in a] != [w.prompt for w in b]
        assert [w.arrival_s for w in a] != [w.arrival_s for w in b]

    def test_shape_and_bounds(self):
        wl = small_workload()
        assert len(wl) == 200
        arrivals = [w.arrival_s for w in wl]
        assert arrivals == sorted(arrivals) and arrivals[0] >= 0
        for w in wl:
            assert 1 <= len(w.prompt) <= 64
            assert 1 <= w.max_new_tokens <= 16
            assert all(0 <= t < VOCAB for t in w.prompt)
            assert w.deadline_s == 2.0

    def test_zipf_prefix_sharing(self):
        """Grouped requests literally share the group's prefix tokens,
        and the Zipf skew makes low ranks strictly more popular in
        aggregate than high ranks."""
        wl = small_workload(n=400)
        grouped = [w for w in wl if w.group >= 0]
        assert grouped  # median prompt (24) > prefix_len (16)
        by_group = {}
        for w in grouped:
            assert len(w.prompt) > 16
            by_group.setdefault(w.group, []).append(w.prompt[:16])
        for members in by_group.values():
            assert len(set(members)) == 1  # identical prefix within a group
        counts = [len(by_group.get(g, [])) for g in range(8)]
        assert sum(counts[:4]) > sum(counts[4:])  # popularity skew
        # ungrouped = short prompts, disjoint by construction
        for w in wl:
            if w.group == -1:
                assert len(w.prompt) <= 16

    def test_rejects_degenerate_prefix(self):
        with pytest.raises(ValueError, match="prefix_len"):
            traffic_replay.build_workload(10, VOCAB, 0, prefix_len=64,
                                          max_prompt=64)

    def test_per_request_sampling_seeds(self):
        """Every arrival carries a workload-seeded sampling seed: pinned
        by the workload seed (reproducible replays) and non-constant
        (requests don't share a stream)."""
        a, b = small_workload(), small_workload()
        assert [w.seed for w in a] == [w.seed for w in b]
        assert len({w.seed for w in a}) > 1
        assert all(0 <= w.seed < 2**31 for w in a)
        c = small_workload(seed=6)
        assert [w.seed for w in a] != [w.seed for w in c]


class TestCommonGenerators:
    def test_make_requests_deterministic(self):
        a = common.make_requests(8, 16, 4, VOCAB, seed=3, shared_prefix=4)
        b = common.make_requests(8, 16, 4, VOCAB, seed=3, shared_prefix=4)
        assert [r.prompt for r in a] == [r.prompt for r in b]
        assert [r.uid for r in a] == list(range(8))
        assert all(r.prompt[:4] == a[0].prompt[:4] for r in a)
        c = common.make_requests(8, 16, 4, VOCAB, seed=4, shared_prefix=4)
        assert [r.prompt for r in a] != [r.prompt for r in c]

    def test_mixed_requests_deterministic(self):
        a = common.mixed_requests(6, 32, 4, VOCAB, seed=2)
        b = common.mixed_requests(6, 32, 4, VOCAB, seed=2)
        assert [r.prompt for r in a] == [r.prompt for r in b]
        lens = [len(r.prompt) for r in a]
        assert lens == [8, 32, 8, 32, 8, 32]  # alternating short/long

    def test_sampling_param_reseeds_per_request(self):
        """``sampling=`` attaches per-uid re-seeded params without
        perturbing the prompt stream (existing workloads replay
        token-identically whether or not sampling is on)."""
        from repro.serve import SamplingParams

        sp = SamplingParams(temperature=0.8, top_p=0.95, seed=100)
        plain = common.make_requests(6, 16, 4, VOCAB, seed=3)
        sampled = common.make_requests(6, 16, 4, VOCAB, seed=3, sampling=sp)
        assert [r.prompt for r in plain] == [r.prompt for r in sampled]
        assert [r.sampling.seed for r in sampled] == list(range(100, 106))
        assert all(r.sampling.temperature == 0.8 for r in sampled)
        assert all(r.sampling.greedy for r in plain)
        mixed = common.mixed_requests(6, 32, 4, VOCAB, seed=2, sampling=sp)
        assert [r.sampling.seed for r in mixed] == list(range(100, 106))

    def test_seeded_prompts_prefix_draw_order(self):
        """shared_prefix=0 must consume nothing from the stream — the
        pre-refactor inline generators drew exactly this way, and the
        committed benchmark history replays their workloads."""
        plain = common.seeded_prompts(4, 12, VOCAB, seed=9)
        with_zero = common.seeded_prompts(4, 12, VOCAB, seed=9,
                                          shared_prefix=0)
        assert plain == with_zero


class TestReplaySmoke:
    @pytest.fixture(scope="class")
    def record(self, tmp_path_factory):
        path = tmp_path_factory.mktemp("bench") / "BENCH_serve.json"
        path.write_text(json.dumps({"rows": [{"mode": "keep-me"}]}))
        rec = traffic_replay.main([
            "--requests", "60", "--seed", "3", "--rps", "150",
            "--batch", "4", "--token-budget", "48", "--max-prompt", "96",
            "--prefix-groups", "6", "--prefix-len", "32",
            "--deadline", "10", "--json", str(path),
        ])
        with open(path) as f:
            merged = json.load(f)
        return rec, merged

    def test_record_schema(self, record):
        rec, _ = record
        assert rec["requests"] == 60
        outcomes = rec["outcomes"]
        assert sum(outcomes.values()) == 60
        for dist in ("ttft_ms", "queue_wait_ms", "admitted_ttft_ms",
                     "tpot_ms"):
            assert set(rec[dist]) == {"mean", "p50", "p99"}
            assert rec[dist]["p50"] <= rec[dist]["p99"]
        good = rec["goodput"]
        assert 0.0 <= good["met_fraction"] <= 1.0
        assert good["met_requests"] <= outcomes["finished"]
        assert good["met_tokens_per_s"] <= good["tokens_per_s"]
        assert rec["engine"]["mode"] == "packed+paged"
        # default replay is stochastic with per-request seeds
        assert rec["sampling"] == {"temperature": 0.8, "top_k": 0,
                                   "top_p": 0.95,
                                   "per_request_seeds": True}

    def test_zero_leaked_pages(self, record):
        rec, _ = record
        assert rec["leaked_pages"] == 0

    def test_json_merge_preserves_existing(self, record):
        rec, merged = record
        assert merged["rows"] == [{"mode": "keep-me"}]
        assert merged["traffic"]["requests"] == rec["requests"]
        assert merged["traffic"]["leaked_pages"] == 0
