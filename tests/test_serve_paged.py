"""Paged KV cache: allocator invariants, paged-vs-dense parity, prefix
sharing, copy-on-write forks, and the typed dist error.

The dense-slot engine is the oracle (same harness as
``tests/test_serve_packed.py``): for every point on the parity matrix the
paged engine must produce identical greedy output streams, TTFT step
counts, and per-step accounting — paging changes *where bytes live*,
never *what is computed*.  Prefix sharing is the exception that proves
the rule: it skips recomputing KV that is bit-identical by construction,
so outputs still match the oracle while prefill steps and page usage
strictly drop.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.model import (
    decode_step,
    init_decode_cache,
    init_params,
    prefill_chunk,
)
from repro.serve import (
    ContinuousBatcher,
    KVCacheSpec,
    KVState,
    OutOfPages,
    PagedTables,
    Request,
    UnsupportedDistError,
)

CFG = ModelConfig(
    name="serve-paged-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab_size=101, layer_pattern="LG", sliding_window=6, dtype="float32", remat=False,
)

# mixed prompt lengths through 2 slots: forces slot reuse and mixed
# decode+prefill steps (same shapes the packed suite exercises)
PROMPT_LENS = (3, 5, 12, 4, 8)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def make_prompts(seed=0, lens=PROMPT_LENS):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=n).tolist() for n in lens]


def run_engine(params, prompts, max_new=4, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 24)
    eng = ContinuousBatcher(params, CFG, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
    eng.run()
    return eng


def assert_engines_match(oracle, eng):
    assert {u: r.output for u, r in oracle.finished.items()} == {
        u: r.output for u, r in eng.finished.items()
    }
    assert {u: r.ttft_steps for u, r in oracle.finished.items()} == {
        u: r.ttft_steps for u, r in eng.finished.items()
    }
    assert oracle.steps == eng.steps
    for sd, sp in zip(oracle.step_stats, eng.step_stats):
        assert (sd.decode_tokens, sd.prefill_tokens, sd.deferred_tokens) == (
            sp.decode_tokens, sp.prefill_tokens, sp.deferred_tokens
        )


# ---------------------------------------------------------------------------
# Paged engine vs dense oracle
# ---------------------------------------------------------------------------


class TestPagedDenseParity:
    @pytest.mark.parametrize("budget", [None, 4])
    @pytest.mark.parametrize("packed", [False, True])
    def test_parity_matrix(self, params, budget, packed):
        """Disjoint prompts: scheduling, outputs, and accounting must be
        byte-identical to the dense oracle (no sharing fires)."""
        prompts = make_prompts()
        dense = run_engine(params, prompts, chunk_size=16, token_budget=budget)
        paged = run_engine(params, prompts, chunk_size=16, token_budget=budget,
                           packed=packed, cache="paged", page_size=8)
        assert_engines_match(dense, paged)
        assert all(s.shared_tokens == 0 for s in paged.step_stats)
        # pages are allocated for actual tokens, not worst case
        assert paged.stats_summary()["peak_used_pages"] <= paged.kv.num_pages

    @pytest.mark.parametrize("chunk", [4, 16])
    def test_parity_small_pages(self, params, chunk):
        """page_size < / == chunk_size, budget-constrained."""
        prompts = make_prompts(seed=1)
        dense = run_engine(params, prompts, chunk_size=chunk, token_budget=6)
        paged = run_engine(params, prompts, chunk_size=chunk, token_budget=6,
                           packed=True, cache="paged", page_size=4)
        assert_engines_match(dense, paged)

    def test_kvcachespec_accepted_directly(self, params):
        spec = KVCacheSpec(num_slots=2, max_len=24, layout="paged", page_size=8)
        eng = run_engine(params, make_prompts(seed=2, lens=(5, 9)), cache=spec)
        assert eng.kv is not None and eng.kv.page_size == 8
        assert sorted(eng.finished) == [0, 1]

    def test_cache_bytes_accounting(self, params):
        """Spec-level byte accounting matches the arrays it builds."""
        spec = KVCacheSpec(num_slots=2, max_len=24, layout="paged", page_size=8)
        kv = spec.build(params, CFG)
        assert kv.memory_bytes() == spec.memory_bytes(CFG)
        dspec = KVCacheSpec(num_slots=2, max_len=24, layout="dense")
        dkv = dspec.build(params, CFG)
        assert dkv.memory_bytes() == dspec.memory_bytes(CFG)


# ---------------------------------------------------------------------------
# Prefix sharing (the acceptance scenario: 256-token shared prefix)
# ---------------------------------------------------------------------------


class TestPrefixSharing:
    def test_shared_prefix_fewer_pages_and_steps(self, params):
        rng = np.random.default_rng(3)
        prefix = rng.integers(0, CFG.vocab_size, size=256).tolist()
        tails = [rng.integers(0, CFG.vocab_size, size=16).tolist() for _ in range(2)]
        disjoint = [rng.integers(0, CFG.vocab_size, size=272).tolist() for _ in range(2)]
        kw = dict(batch_slots=2, max_len=288, chunk_size=16)

        def serve_two(eng, prompts):
            for i, p in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=4))
                eng.run()  # sequential: the second request arrives after
            return eng  # the first finished (its pages are prefix-cached)

        shared = serve_two(
            ContinuousBatcher(params, CFG, cache="paged", page_size=16, **kw),
            [prefix + tails[0], prefix + tails[1]],
        )
        control = serve_two(
            ContinuousBatcher(params, CFG, cache="paged", page_size=16, **kw),
            disjoint,
        )
        oracle = serve_two(
            ContinuousBatcher(params, CFG, **kw),
            [prefix + tails[0], prefix + tails[1]],
        )

        # outputs identical to the dense oracle despite skipping 256
        # prompt tokens of compute (shared-prefix KV is bit-identical)
        assert {u: r.output for u, r in shared.finished.items()} == {
            u: r.output for u, r in oracle.finished.items()
        }
        assert sum(s.shared_tokens for s in shared.step_stats) == 256
        # strictly fewer page-pool rows than two disjoint requests...
        assert shared.kv.tables.touched_pages < control.kv.tables.touched_pages
        # ...and strictly fewer prefill steps for the second request
        assert (
            shared.finished[1].ttft_steps < control.finished[1].ttft_steps
        )
        assert shared.finished[1].ttft_steps < oracle.finished[1].ttft_steps
        # first requests pay full price in both engines
        assert shared.finished[0].ttft_steps == control.finished[0].ttft_steps

    def test_sharing_caps_before_last_prompt_token(self, params):
        """A prompt that is an exact page multiple of a cached prefix must
        still process >= 1 token (its last-position logits seed decode)."""
        rng = np.random.default_rng(4)
        prefix = rng.integers(0, CFG.vocab_size, size=32).tolist()
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=48,
                                chunk_size=16, cache="paged", page_size=16)
        eng.submit(Request(uid=0, prompt=list(prefix), max_new_tokens=2))
        eng.run()
        eng.submit(Request(uid=1, prompt=list(prefix), max_new_tokens=2))
        eng.run()
        # block 1 covers positions 16..31 = the prompt's end: not shareable
        assert sum(s.shared_tokens for s in eng.step_stats) == 16
        dense = ContinuousBatcher(params, CFG, batch_slots=2, max_len=48, chunk_size=16)
        dense.submit(Request(uid=0, prompt=list(prefix), max_new_tokens=2))
        dense.run()
        assert eng.finished[1].output == dense.finished[0].output


class TestInflightPrefixDedup:
    def test_identical_prompts_dedup_in_flight(self, params):
        """Two identical prompts submitted together: the follower is
        parked at admission until the leader's prefix pages land, then
        maps them — one prefill step instead of re-prefilling the whole
        prompt in lockstep (the PR-4 known gap)."""
        rng = np.random.default_rng(9)
        prompt = rng.integers(0, CFG.vocab_size, size=48).tolist()
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=64,
                                chunk_size=16, cache="paged", page_size=16)
        for uid in range(2):
            eng.submit(Request(uid=uid, prompt=list(prompt), max_new_tokens=4))
        eng.run()
        eng.kv.tables.check_invariants()
        leader, follower = eng.finished[0], eng.finished[1]
        assert leader.ttft_steps == 3  # 48 tokens / chunk 16
        assert follower.ttft_steps == 1  # maps 2 shared pages, prefills 16
        # full blocks strictly before the prompt's last token are shared
        assert sum(s.shared_tokens for s in eng.step_stats) == 32
        # 48 + 16 prompt tokens computed, not 96
        assert sum(s.prefill_tokens for s in eng.step_stats) == 64
        assert follower.output == leader.output

        dense = ContinuousBatcher(params, CFG, batch_slots=2, max_len=64,
                                  chunk_size=16)
        dense.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=4))
        dense.run()
        assert leader.output == dense.finished[0].output

    def test_disjoint_prompts_not_parked(self, params):
        """Dedup must never park prompts that share nothing: both admit
        immediately and prefill concurrently."""
        rng = np.random.default_rng(10)
        prompts = [rng.integers(0, CFG.vocab_size, size=48).tolist()
                   for _ in range(2)]
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=64,
                                chunk_size=16, cache="paged", page_size=16)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
        eng.step()
        assert all(not s.free for s in eng.slots)  # both admitted at step 0
        eng.run()
        assert sum(s.shared_tokens for s in eng.step_stats) == 0

    def test_parking_is_bounded(self, params):
        """The parked follower admits once the leader stops prefilling —
        even when pool pressure evicted the leader's cached pages."""
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, CFG.vocab_size, size=32).tolist()
        # pool so tight the leader's pages cannot be retained for sharing
        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=48,
                                chunk_size=16, cache="paged", page_size=16,
                                num_pages=3)
        for uid in range(2):
            eng.submit(Request(uid=uid, prompt=list(prompt), max_new_tokens=4))
        eng.run(max_steps=200)
        assert sorted(eng.finished) == [0, 1]
        assert eng.finished[0].output == eng.finished[1].output


# ---------------------------------------------------------------------------
# Fork + copy-on-write at the model level
# ---------------------------------------------------------------------------


class TestForkCow:
    def test_fork_decode_matches_dense(self, params):
        """Fork a slot mid-request, decode the two branches with different
        tokens: COW must keep them isolated, logits matching a dense cache
        that prefilled both slots independently."""
        rng = np.random.default_rng(5)
        prompt = rng.integers(0, CFG.vocab_size, size=7).tolist()
        spec = KVCacheSpec(num_slots=2, max_len=24, layout="paged", page_size=4)
        kv = spec.build(params, CFG)
        toks = np.zeros((2, 7), np.int32)
        toks[0] = prompt
        kv.prepare_step([(0, 0, prompt)])
        _, kv.state = prefill_chunk(
            params, CFG, kv.state, jnp.asarray(toks),
            jnp.asarray([0, 0], jnp.int32), jnp.asarray([7, 0], jnp.int32))
        kv.fork_slot(0, 1)
        assert kv.tables.ref.count(2) == 2  # both prompt pages shared

        dense = init_decode_cache(params, CFG, 2, 24, linear=True)
        toks[1] = prompt
        _, dense = prefill_chunk(
            params, CFG, dense, jnp.asarray(toks),
            jnp.asarray([0, 0], jnp.int32), jnp.asarray([7, 7], jnp.int32))

        pos = [7, 7]
        step_toks = np.asarray([[11], [93]], np.int32)  # branches diverge
        for _ in range(3):
            kv.prepare_step([(0, pos[0], [0]), (1, pos[1], [0])])
            lg_p, kv.state = decode_step(
                params, CFG, kv.state, jnp.asarray(step_toks),
                jnp.asarray(pos, jnp.int32))
            lg_d, dense = decode_step(
                params, CFG, dense, jnp.asarray(step_toks),
                jnp.asarray(pos, jnp.int32))
            np.testing.assert_allclose(
                np.asarray(lg_p), np.asarray(lg_d), atol=1e-5)
            step_toks = np.asarray(jnp.argmax(lg_d[:, -1], axis=-1))[:, None].astype(np.int32)
            pos = [p + 1 for p in pos]
        # the written block was copied; untouched prefix page still shared
        kv.tables.check_invariants()
        assert kv.tables.ref.count(2) == 1


# ---------------------------------------------------------------------------
# Allocator invariants (deterministic + hypothesis)
# ---------------------------------------------------------------------------


class TestAllocator:
    def test_cow_on_shared_block(self):
        t = PagedTables(num_slots=2, num_blocks=4, num_pages=8, page_size=4)
        assert t.admit(0, list(range(6)), 2) == 0
        t.prepare_write(0, 0, 6)
        t.fork(0, 1)
        assert t.ref[t.tables[0][1]] == 2
        ops = t.prepare_write(1, 6, 1)  # position 6 -> shared block 1
        assert len(ops) == 1
        src, dst = ops[0]
        assert t.tables[0][1] == src and t.tables[1][1] == dst
        assert t.ref[src] == 1 and t.ref[dst] == 1
        t.check_invariants()

    def test_refcount_zero_exactly_on_last_free(self):
        t = PagedTables(num_slots=2, num_blocks=4, num_pages=8, page_size=4)
        prompt = list(range(9))  # blocks 0,1 full + block 2 partial
        t.admit(0, prompt, 1)
        t.prepare_write(0, 0, 9)
        t.register_prompt_pages(0, prompt, 9)
        shared = t.admit(1, prompt, 1)
        assert shared == 8  # two full pages shared (cap leaves pos 8)
        page = t.tables[0][0]
        assert t.ref[page] == 2
        t.free_slot(0)
        assert t.ref[page] == 1  # other sharer still holds it
        t.free_slot(1)
        assert t.ref[page] == 0
        assert t.used_pages == 0
        # registered pages are retained (cached), not recycled
        assert t.cached_pages == 2 and t.free_pages == 6
        t.check_invariants()

    def test_admission_denied_then_freed(self):
        t = PagedTables(num_slots=2, num_blocks=4, num_pages=4, page_size=4)
        assert t.admit(0, list(range(10)), 4) == 0  # needs 4 blocks
        assert t.admit(1, list(range(10)), 4) is None  # pool exhausted by reservation
        t.prepare_write(0, 0, 10)
        t.free_slot(0)
        assert t.admit(1, list(range(10)), 4) == 0
        t.check_invariants()

    def test_eviction_reclaims_cached_pages(self):
        t = PagedTables(num_slots=2, num_blocks=4, num_pages=4, page_size=4)
        prompt = list(range(12))
        t.admit(0, prompt, 4)
        t.prepare_write(0, 0, 12)
        t.register_prompt_pages(0, prompt, 12)
        t.free_slot(0)
        assert t.cached_pages == 3 and t.free_pages == 1
        other = list(range(50, 62))
        t.admit(1, other, 4)
        t.prepare_write(1, 0, 12)  # 3 allocs: 1 free + 2 LRU evictions
        assert t.free_pages == 0 and t.cached_pages == 1
        t.check_invariants()

    def test_impossible_request_raises_not_livelocks(self, params):
        """A request whose worst case exceeds the whole pool must be
        rejected loudly (FIFO admission would otherwise park it — and
        everything queued behind it — forever)."""
        from repro.serve import AdmissionError

        eng = ContinuousBatcher(params, CFG, batch_slots=2, max_len=24,
                                chunk_size=4, cache="paged", page_size=4,
                                num_pages=3)  # plen 16 + 4 new needs 5 pages
        with pytest.raises(AdmissionError, match="pages"):
            eng.submit(Request(uid=0, prompt=list(range(16)), max_new_tokens=4))
        t = PagedTables(num_slots=2, num_blocks=6, num_pages=3, page_size=4)
        with pytest.raises(Exception, match="never fit"):
            t.admit(0, list(range(16)), 4)

    def test_spec_mismatch_raises_typed(self, params):
        spec = KVCacheSpec(num_slots=4, max_len=48, layout="paged")
        with pytest.raises(ValueError, match="disagrees"):
            ContinuousBatcher(params, CFG, batch_slots=2, max_len=24, cache=spec)

    def test_out_of_pages_on_unreserved_path(self):
        t = PagedTables(num_slots=2, num_blocks=4, num_pages=2, page_size=4)
        t.admit(0, list(range(5)), 3)
        t.prepare_write(0, 0, 5)
        t.fork(0, 1)  # unreserved
        with pytest.raises(OutOfPages):
            t.prepare_write(1, 5, 4)  # COW + new block with an empty pool
        t.check_invariants()


# ---------------------------------------------------------------------------
# Page-accounting reset (the bench warmup workaround's replacement)
# ---------------------------------------------------------------------------


class TestAccountingReset:
    def test_reset_rebaselines_touched_pages(self, params):
        """warmup -> reset_stats -> run: touched_pages counts only the
        pages the post-reset run allocated — identical to what a fresh
        engine serving the same workload reports."""
        eng = run_engine(params, make_prompts(seed=7), cache="paged",
                         page_size=4)
        assert eng.kv.tables.touched_pages > 0
        eng.reset_stats()
        assert eng.kv.tables.touched_pages == 0
        for i, p in enumerate(make_prompts(seed=3)):
            eng.submit(Request(uid=100 + i, prompt=list(p), max_new_tokens=4))
        eng.run()
        fresh = run_engine(params, make_prompts(seed=3), cache="paged",
                           page_size=4)
        assert eng.kv.tables.touched_pages == fresh.kv.tables.touched_pages
        assert {u - 100: r.output for u, r in eng.finished.items()
                if u >= 100} == {u: r.output for u, r in fresh.finished.items()}

    def test_reset_keeps_cached_pages_live(self, params):
        """Rebaselining is not a flush: prefix-cached pages survive the
        reset (a repeat workload still maps them), they just stop being
        counted."""
        eng = run_engine(params, make_prompts(), cache="paged", page_size=4)
        eng.reset_stats()
        for i, p in enumerate(make_prompts()):
            eng.submit(Request(uid=100 + i, prompt=list(p), max_new_tokens=4))
        eng.run()
        fresh = run_engine(params, make_prompts(), cache="paged", page_size=4)
        # cached pages from the pre-reset run were mapped, not re-written
        assert sum(s.shared_tokens for s in eng.step_stats) > 0
        assert eng.kv.tables.touched_pages < fresh.kv.tables.touched_pages
        assert {u - 100: r.output for u, r in eng.finished.items()
                if u >= 100} == {u: r.output for u, r in fresh.finished.items()}


# ---------------------------------------------------------------------------
# Hostile block tables: reads can be redirected only to zeros
# ---------------------------------------------------------------------------


class TestHostileTables:
    def test_paged_gather_zero_masks_invalid_entries(self):
        from repro.models.layers import paged_gather

        num_pages, ps = 4, 2
        pool = (jnp.arange(num_pages * ps * 1 * 3, dtype=jnp.float32)
                .reshape(num_pages, ps, 1, 3) + 1.0)  # no zero rows
        tables = jnp.asarray(
            [[1, num_pages, -1, num_pages + 5], [3, 2, 1, 0]], jnp.int32
        )
        out = paged_gather(pool, tables, jnp.asarray([0, 1], jnp.int32))
        out = np.asarray(out).reshape(2, 4, ps, 1, 3)
        np.testing.assert_array_equal(out[0, 0], np.asarray(pool[1]))
        assert (out[0, 1:] == 0).all()  # sentinel/negative/overflow -> zeros
        for b, page in enumerate([3, 2, 1, 0]):
            np.testing.assert_array_equal(out[1, b], np.asarray(pool[page]))

    def test_hostile_table_cannot_change_other_slots_output(self, params):
        """Corrupting slot 1's block table (sentinel, negative, and
        out-of-range entries) leaves slot 0's fused attention output
        bit-identical, and slot 1 still reads only zeros-or-own-pages
        (finite output, no NaN from another slot's data)."""
        from repro.kernels.ops import paged_flash_attention

        rng = np.random.default_rng(5)
        num_pages, ps, kvh, d = 6, 4, 1, 8
        k_pool = jnp.asarray(rng.standard_normal((num_pages, ps, kvh, d)),
                             jnp.float32)
        v_pool = jnp.asarray(rng.standard_normal((num_pages, ps, kvh, d)),
                             jnp.float32)
        q = jnp.asarray(rng.standard_normal((2, 2, d)), jnp.float32)
        q_pos = jnp.asarray([7, 7], jnp.int32)
        q_slots = jnp.asarray([0, 1], jnp.int32)
        clean = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
        hostile = jnp.asarray([[0, 1], [-1, num_pages + 3]], jnp.int32)
        out_clean = np.asarray(paged_flash_attention(
            q, k_pool, v_pool, clean, q_pos, q_slots))
        out_host = np.asarray(paged_flash_attention(
            q, k_pool, v_pool, hostile, q_pos, q_slots))
        np.testing.assert_array_equal(out_host[0], out_clean[0])
        assert np.isfinite(out_host[1]).all()
        # every read redirected to zeros: softmax over zero keys is
        # uniform over the causal span, value rows are zero
        np.testing.assert_array_equal(out_host[1], np.zeros_like(out_host[1]))


# ---------------------------------------------------------------------------
# int8 KV pages: allclose tier + admission math
# ---------------------------------------------------------------------------


class TestInt8Pages:
    def test_int8_engine_token_match_tier(self, params):
        """int8 pages are allclose, not bit-identical: stream lengths must
        equal the dense oracle's and >= 90% of tokens must match."""
        dense = run_engine(params, make_prompts())
        int8 = run_engine(params, make_prompts(), packed=True, cache="paged",
                          page_size=4, kv_dtype="int8")
        oracle = {u: r.output for u, r in dense.finished.items()}
        got = {u: r.output for u, r in int8.finished.items()}
        assert set(got) == set(oracle)
        assert all(len(got[u]) == len(oracle[u]) for u in oracle)
        total = sum(len(v) for v in oracle.values())
        same = sum(a == b for u in oracle
                   for a, b in zip(got[u], oracle[u]))
        assert same / total >= 0.9, f"token match {same}/{total}"
        assert int8.kv.used_pages == 0

    def test_int8_state_has_scale_leaves(self, params):
        spec = KVCacheSpec(num_slots=2, max_len=24, layout="paged",
                           page_size=8, kv_dtype="int8")
        kv = spec.build(params, CFG)
        flat = jax.tree_util.tree_leaves_with_path(kv.state.data)
        names = {".".join(str(getattr(k, "key", k)) for k in kp): x
                 for kp, x in flat}
        k_pools = [x for n, x in names.items() if n.endswith("attn.k")]
        scales = [x for n, x in names.items() if n.endswith("k_scale")]
        assert k_pools and all(x.dtype == jnp.int8 for x in k_pools)
        assert scales and all(x.dtype == jnp.float32 for x in scales)
        assert kv.memory_bytes() == spec.memory_bytes(CFG)

    def test_int8_admits_double_pages_at_fixed_bytes(self, params):
        """The point of quantized pages: a fixed pool-byte budget holds
        ~2x the pages (half-width rows, f32 scales are the overhead)."""
        mk = lambda dt: KVCacheSpec(num_slots=2, max_len=24, layout="paged",
                                    page_size=8, kv_dtype=dt)
        bf16, int8 = mk("bfloat16"), mk("int8")
        budget = 8 * bf16.bytes_per_page(CFG)
        ratio = int8.pages_for_bytes(CFG, budget) / bf16.pages_for_bytes(CFG, budget)
        # head_dim=16 is the worst case for the f32-scale overhead (exactly
        # 1.6x per token, 1.5x after the page floor); production head dims
        # clear 1.75x — BENCH_serve.json's int8_admission record gates that
        assert ratio >= 1.5
        assert int8.bytes_per_page(CFG) < bf16.bytes_per_page(CFG)


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property sweep is extra depth, not the only coverage
    HAVE_HYPOTHESIS = False

    def given(*a, **k):  # keep the decorated defs importable
        return lambda f: f

    settings = given

    class st:  # noqa: N801
        @staticmethod
        def _none(*a, **k):
            return None

        lists = tuples = integers = _none


ops_strategy = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=3),   # op: admit/write/finish/fork
        st.integers(min_value=0, max_value=2),   # slot
        st.integers(min_value=1, max_value=12),  # prompt len / write size
        st.integers(min_value=1, max_value=4),   # max_new
    ),
    min_size=1, max_size=40,
)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestAllocatorProperties:
    @settings(max_examples=60, deadline=None)
    @given(ops_strategy, st.integers(min_value=0, max_value=2**31 - 1))
    def test_no_leak_no_double_free(self, ops, seed):
        """Arbitrary admit / write+register / finish / fork sequences:
        pages are conserved, refcounts equal table occurrences (zero
        exactly when the last sharer frees), nothing double-frees."""
        rng = np.random.default_rng(seed)
        t = PagedTables(num_slots=3, num_blocks=4, num_pages=20, page_size=4)
        live = {}  # slot -> (prompt, pos, limit)
        for op, slot, a, b in ops:
            if op == 0 and slot not in live and not t.tables[slot]:
                prompt = rng.integers(0, 97, size=a).tolist()
                shared = t.admit(slot, prompt, b)
                if shared is not None:
                    live[slot] = [prompt, shared, a + b]
            elif op == 1 and slot in live:
                prompt, pos, limit = live[slot]
                n = min(a, limit - pos)
                if n > 0:
                    try:
                        t.prepare_write(slot, pos, n)
                    except OutOfPages:
                        pass  # fork-driven overcommit; invariants must hold
                    else:
                        live[slot][1] = pos + n
                        t.register_prompt_pages(slot, prompt, pos + n)
            elif op == 2 and slot in live:
                t.free_slot(slot)
                del live[slot]
            elif op == 3 and slot in live:
                child = next(
                    (c for c in range(3) if c not in live and not t.tables[c]),
                    None,
                )
                if child is not None:
                    t.fork(slot, child)
                    live[child] = [list(live[slot][0]), live[slot][1], live[slot][2]]
            t.check_invariants()
        for slot in list(live):
            t.free_slot(slot)
        t.check_invariants()
        assert t.used_pages == 0
        assert all(r == 0 for r in t.ref)

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=30), min_size=1, max_size=6),
           st.integers(min_value=1, max_value=8))
    def test_device_tables_consistent(self, lens, ps):
        """The dense device view always mirrors the host tables, sentinel
        included."""
        nb = -(-max(lens) // ps)
        t = PagedTables(num_slots=len(lens), num_blocks=nb,
                        num_pages=len(lens) * nb, page_size=ps)
        for s, n in enumerate(lens):
            assert t.admit(s, list(range(n)), 0) == 0
            t.prepare_write(s, 0, n)
        arr = t.device_tables()
        for s, n in enumerate(lens):
            k = -(-n // ps)
            assert list(arr[s, :k]) == t.tables[s]
            assert all(arr[s, k:] == t.num_pages)
        t.check_invariants()


# ---------------------------------------------------------------------------
# Distribution interplay
# ---------------------------------------------------------------------------


class TestDistInterplay:
    def _dist(self):
        from repro.dist import Distribution

        return Distribution.from_spec("1")

    def test_packed_dist_typed_error(self, params):
        with pytest.raises(UnsupportedDistError, match="ROADMAP"):
            ContinuousBatcher(params, CFG, batch_slots=2, max_len=24,
                              packed=True, dist=self._dist())
        # the typed error still satisfies pre-existing handlers
        with pytest.raises(NotImplementedError):
            ContinuousBatcher(params, CFG, batch_slots=2, max_len=24,
                              packed=True, dist=self._dist())

    def test_paged_dist_typed_error(self, params):
        with pytest.raises(UnsupportedDistError, match="ROADMAP"):
            ContinuousBatcher(params, CFG, batch_slots=2, max_len=24,
                              cache="paged", dist=self._dist())

    def test_cache_shardings_learn_paged_pytree(self, params):
        from jax.sharding import NamedSharding

        from repro.dist.sharding import cache_shardings
        from repro.dist.mesh import make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
        spec = KVCacheSpec(num_slots=2, max_len=24, layout="paged", page_size=8)
        kv = spec.build(params, CFG)
        sh = cache_shardings(kv.state, mesh)
        assert isinstance(sh, KVState) and sh.page_size == kv.state.page_size
        assert isinstance(sh.tables, NamedSharding)
        assert not sh.tables.spec  # block tables replicated
        leaves = jax.tree_util.tree_leaves(
            sh.data, is_leaf=lambda x: isinstance(x, NamedSharding)
        )
        assert leaves and all(isinstance(x, NamedSharding) for x in leaves)
        # structure congruence: usable as jit shardings for the state
        jax.tree.map(lambda a, b: None, kv.state.data, sh.data)


# ---------------------------------------------------------------------------
# Slow lane: undersized-pool soak
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestPagedSoak:
    def test_soak_oversubscribed_pool(self, params):
        """64 staggered requests through a pool half the worst case:
        admission gates on reservations, everything finishes, no page
        leaks, the pool bound is honored every step."""
        rng = np.random.default_rng(7)
        eng = ContinuousBatcher(
            params, CFG, batch_slots=8, max_len=64, chunk_size=16,
            token_budget=12, packed=True, cache="paged", page_size=16,
            num_pages=16,  # worst case would be 8 slots * 4 blocks = 32
        )
        pending = [
            Request(uid=i, prompt=rng.integers(0, CFG.vocab_size, size=n).tolist(),
                    max_new_tokens=8)
            for i, n in enumerate(rng.integers(4, 40, size=64))
        ]
        while pending or eng.busy:
            for _ in range(3):
                if pending:
                    eng.submit(pending.pop(0))
            for _ in range(4):
                if eng.busy:
                    eng.step()
            eng.kv.tables.check_invariants()
        assert sorted(eng.finished) == list(range(64))
        assert all(len(r.output) == 8 for r in eng.finished.values())
        assert all(s.used_pages <= 16 for s in eng.step_stats)
        assert eng.kv.used_pages == 0  # every page came back
